"""Fast interpret-mode trace-count regression guard (CI, scripts/check.sh).

Asserts the PR-4 fusion contract at the jaxpr level — cheap (no kernel
execution, just tracing) and robust to interpret mode, where pallas_calls
lower to plain HLO and can't be counted post-compilation:

  * ONE FNO block forward on the full-fusion pallas path traces to
    exactly one pallas_call (spectral + bypass GEMM + bias + GELU all
    inside the engine's k-loop/epilogue);
  * jax.grad of the block traces to exactly four (fwd + gz recompute +
    dx adjoint + extended wgrad) — every cotangent on fused kernels;
  * a whole apply_fno forward with cfg.fuse_block traces to exactly
    num_layers pallas_calls.
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import fno as fno_mod
from repro.kernels import ops
from repro.roofline.hlo_counter import count_pallas_calls


def main() -> None:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 8, 16, 32))
    wr = jax.random.normal(key, (6, 8)) / 8
    wi = jax.random.normal(key, (6, 8)) / 8
    wb = jax.random.normal(key, (6, 8)) / 8
    bias = jnp.zeros((6,))
    modes = (5, 9)

    block = lambda *a: ops.fno_block_nd(*a, modes, path="pallas",
                                        variant="full")
    n = count_pallas_calls(block, x, wr, wi, wb, bias)
    assert n == 1, f"fused block forward traced {n} pallas_calls, want 1"

    loss = lambda *a: jnp.sum(block(*a) ** 2)
    grad = lambda *a: jax.grad(loss, argnums=(0, 1, 2, 3, 4))(*a)
    n = count_pallas_calls(grad, x, wr, wi, wb, bias)
    assert n == 4, f"fused block grad traced {n} pallas_calls, want 4"

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              fuse_block=True)
    params = fno_mod.init_fno(key, cfg)
    xin = jax.random.normal(key, (2, cfg.in_channels, *cfg.spatial))
    model = lambda xx: fno_mod.apply_fno(params, cfg, xx, path="pallas")
    n = count_pallas_calls(model, xin)
    assert n == cfg.num_layers, (
        f"fused-block model traced {n} pallas_calls, want {cfg.num_layers}")
    print(f"fused-block smoke OK: block fwd=1, grad=4, "
          f"model={cfg.num_layers} pallas_calls ({cfg.num_layers} layers)")


if __name__ == "__main__":
    main()
