"""Fast interpret-mode trace-count regression guard (CI, scripts/check.sh).

Asserts the PR-4 fusion contract at the jaxpr level — cheap (no kernel
execution, just tracing) and robust to interpret mode, where pallas_calls
lower to plain HLO and can't be counted post-compilation:

  * ONE FNO block forward on the full-fusion pallas path traces to
    exactly one pallas_call (spectral + bypass GEMM + bias + GELU all
    inside the engine's k-loop/epilogue);
  * jax.grad of the block traces to exactly four (fwd + gz recompute +
    dx adjoint + extended wgrad) — every cotangent on fused kernels;
  * a whole apply_fno forward with cfg.fuse_block traces to exactly
    num_layers pallas_calls.

Since ISSUE 6 this is a thin wrapper over the contract-linter framework
(``repro.analysis.jaxpr_lint.fused_block_contract``) — the same checkers
``scripts/lint.py --trace`` sweeps over the full config matrix — so the
CI step name and its pass/fail semantics are unchanged while the logic
lives in exactly one place.
"""
import sys

from repro.analysis import format_findings
from repro.analysis.jaxpr_lint import fused_block_contract
from repro.configs import get_config


def main() -> None:
    findings = fused_block_contract()
    if findings:
        print(format_findings(findings), file=sys.stderr)
        raise AssertionError(
            f"fused-block contract violated ({len(findings)} finding(s))")
    layers = get_config("fno2d", reduced=True).num_layers
    print(f"fused-block smoke OK: block fwd=1, grad=4, "
          f"model={layers} pallas_calls ({layers} layers)")


if __name__ == "__main__":
    main()
