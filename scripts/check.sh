#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md §Tier-1 verify): the full suite must pass with
# zero collection errors. Run from anywhere; extra args forwarded to pytest
# (e.g. scripts/check.sh -x -k kernels).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH}
# Fused-block trace-count guard (PR 4): one FNO block on the full-fusion
# pallas path must stay exactly ONE pallas_call (and its grad exactly
# four). Pure tracing — runs in a couple of seconds, no kernels execute.
python scripts/fused_block_smoke.py
# FNO serving smoke (ISSUE 5): the batched serve driver on the fused
# pallas path, one bucket — asserts one pallas_call per layer through the
# sharded dispatch and that every served output is finite.
python -m repro.launch.serve --arch fno2d --reduced --requests 2 \
  --max-batch 2
# TP overlap smoke (ISSUE 8): the scattered layout's ppermute-ring
# overlap mode vs the one-shot psum_scatter on a forced dp2xtp4 mesh —
# forward/grad parity plus the exact traced collective plan ((tp-1)
# ppermutes per interior layer, one final psum).
python scripts/overlap_smoke.py
# Autotuner smoke (ISSUE 7): the generate -> VMEM-prune -> persist
# pipeline over the reduced shapes into a throwaway cache, then the
# staleness lint over it. Pure python byte-model math — seconds, no jax.
python scripts/autotune.py --smoke
# Continuous-batching replay smoke (ISSUE 10): a seeded traffic replay
# through the async coalescing queue on a virtual clock — exact shed/
# coalesce/deadline counts, the no-late-serving deadline contract, replay
# determinism, and the rollout trace contract (K-step device-resident
# rollout == num_layers pallas_calls for K in {1,4} — docs/DESIGN.md §10).
python scripts/serve_replay_smoke.py
# Chaos smoke (ISSUE 9): the deterministic fault plan (kernel fault, NaN
# injection, replica kill, corrupt checkpoint) replayed through the
# resilient serving runtime — every accepted request answered finite,
# degraded/shed counts exactly match the plan, XLA-fallback parity
# <= 2e-4, corrupt-checkpoint reload rolls back (docs/DESIGN.md §9).
python scripts/chaos_smoke.py
# Contract lint (ISSUE 6/7): AST rules, config-registry audit, static
# VMEM estimates (tuned plans, error severity), tuned-cache staleness,
# and the jaxpr trace lints (pallas counts / cast ownership / collective
# budget) over the whole config matrix. Pure tracing + AST — no kernels
# execute.
python scripts/lint.py --all
# Collection gate: when pytest selection args (-k/-m/paths) could deselect
# a broken module, a full collect-only pass must still fail the script on
# any collection error. A bare run needs no gate — pytest itself exits
# nonzero on collection errors.
if [ "$#" -gt 0 ]; then
  python -m pytest -q --collect-only >/dev/null
fi
exec python -m pytest -q "$@"
