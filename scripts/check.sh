#!/usr/bin/env bash
# Tier-1 gate (ROADMAP.md §Tier-1 verify): the full suite must pass with
# zero collection errors. Run from anywhere; extra args forwarded to pytest
# (e.g. scripts/check.sh -x -k kernels).
set -euo pipefail
cd "$(dirname "$0")/.."
PYTHONPATH=src${PYTHONPATH:+:$PYTHONPATH} exec python -m pytest -q "$@"
