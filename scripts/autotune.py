#!/usr/bin/env python
"""Block-size autotuner CLI (docs/DESIGN.md §8).

Regenerates the committed tuned-plan cache
(``src/repro/tuning/cache/blocks.json``): for every tuning key the config
matrix can emit, generate the (bb, bo, bh) candidate grid, prune it
statically against the VMEM budget (``analysis.vmem.launch_estimate``),
wall-time the top survivors where the probe is small enough to interpret,
and persist the winners with their evidence. The committed cache is what
``repro.tuning.resolve_launch_plans`` serves; without it every launch
falls back to the static ``ops._BLOCK_DEFAULTS``.

Usage:
  PYTHONPATH=src python scripts/autotune.py                 # full regen
  PYTHONPATH=src python scripts/autotune.py --measure none  # static only
  PYTHONPATH=src python scripts/autotune.py --smoke         # CI smoke

--smoke tunes only the reduced shapes with static scoring into a
throwaway file, then lints it with ``store.check_tuning_cache`` —
a seconds-long pipeline check that never touches the committed cache.
"""
import argparse
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--measure", choices=("auto", "all", "none"),
                    default="auto",
                    help="wall-time top candidates: auto (small probes "
                         "only, default), all, none (static scores)")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced shapes, static scoring, throwaway "
                         "output + staleness lint (CI)")
    ap.add_argument("--out", default=None,
                    help="cache path (default: the committed "
                         "src/repro/tuning/cache/blocks.json)")
    ap.add_argument("--iters", type=int, default=5,
                    help="timing iterations per measured candidate")
    args = ap.parse_args()

    from repro.tuning import autotune, check_tuning_cache

    if args.smoke:
        with tempfile.TemporaryDirectory() as td:
            out = os.path.join(td, "blocks.json")
            path, entries = autotune.tune(measure="none", smoke=True,
                                          out=out)
            findings = [f for f in check_tuning_cache(path)
                        if f.severity == "error"]
            for f in findings:
                print(f"  error: {f.target}: {f.message}")
            print(f"autotune smoke: {len(entries)} entries, "
                  f"{len(findings)} lint error(s)")
            return 1 if findings or not entries else 0

    path, entries = autotune.tune(measure=args.measure, out=args.out,
                                  iters=args.iters)
    findings = [f for f in check_tuning_cache(path)
                if f.severity == "error"]
    for f in findings:
        print(f"  error: {f.target}: {f.message}")
    return 1 if findings or not entries else 0


if __name__ == "__main__":
    sys.exit(main())
