#!/usr/bin/env python
"""Continuous-batching replay smoke (ISSUE 10, docs/DESIGN.md §10).

Replays a seeded Poisson-ish arrival schedule through the async
continuous-batching tier (``train/serve_queue``) over the REAL fused
serving engine, on a virtual clock with a FIXED synthetic service model —
so every count below is machine-independent and asserted exactly:

  * determinism — two replays of the same schedule produce identical
    reports (stats, latencies, queue depths);
  * exact admission/coalescing counts — shed, batches, coalesced,
    deadline_exceeded are pinned to the schedule's known-good values;
  * conservation — offered == accepted + shed and
    accepted == completed + deadline_exceeded + failed;
  * the deadline contract — no request is served past its deadline
    (every completed request's t_complete <= its deadline), so completed
    p99 <= the deadline by construction;
  * the rollout trace contract — a K-step device-resident rollout traces
    exactly num_layers pallas_calls for K in {1, 4}
    (``analysis.jaxpr_lint.lint_rollout``);
  * every served output is finite (the engine really ran).

Wired into scripts/check.sh and a named CI step. Pure CPU, seconds.

Usage: PYTHONPATH=src python scripts/serve_replay_smoke.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import get_config  # noqa: E402
from repro.core import fno as fno_mod  # noqa: E402
from repro.train import serve_fno_step as sfs  # noqa: E402
from repro.train import serve_queue as sq  # noqa: E402

# The schedule and its exact expected outcome. The counts are a pure
# function of (SEED, REQUESTS, RATE_HZ, MAX_N, DEADLINE_S, QUEUE_LIMIT,
# COALESCE_S, the synthetic service model, and the bucket ladder) — if a
# change to the batch-formation policy moves them, that is a behavior
# change to review, not noise to re-bake silently.
SEED = 0
REQUESTS = 24
RATE_HZ = 600.0
MAX_N = 4
ROLLOUT_STEPS = 2
DEADLINE_S = 0.015
QUEUE_LIMIT = 6
COALESCE_S = 0.004
SERVICE_MODEL = lambda bucket, steps: 1e-3 * steps + 2.5e-4 * bucket  # noqa: E731

# This schedule exercises EVERY admission outcome: sheds (bounded queue),
# a deadline miss (failed with DeadlineExceeded, never served late), and
# real coalescing (10 requests ride along in another request's batch).
EXPECTED = {"offered": 24, "accepted": 20, "shed": 4, "completed": 19,
            "deadline_exceeded": 1, "failed": 0, "batches": 9,
            "coalesced": 10}


def run_once(server):
    cbs = sq.ContinuousBatchingServer(
        server, queue_limit=QUEUE_LIMIT, coalesce_s=COALESCE_S,
        clock=sq.VirtualClock(), service_model=SERVICE_MODEL)
    sched = sq.poisson_schedule(SEED, REQUESTS, rate_hz=RATE_HZ,
                                max_n=MAX_N, rollout_steps=ROLLOUT_STEPS,
                                deadline_s=DEADLINE_S)
    cfg = server.cfg
    key = jax.random.PRNGKey(SEED)

    def input_fn(a, i):
        return np.asarray(jax.random.normal(
            jax.random.fold_in(key, i),
            (a.n, cfg.in_channels) + tuple(cfg.spatial)))

    return cbs, cbs.replay(sched, input_fn)


def main() -> int:
    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    server = sfs.FNOServer(cfg, params, max_batch=MAX_N)

    cbs, rep = run_once(server)
    s = rep["stats"]
    print(f"replay: stats={s}")
    print(f"        latency p50={rep['latency']['p50']*1e3:.2f}ms "
          f"p99={rep['latency']['p99']*1e3:.2f}ms  "
          f"queue p50={rep['queue_depth']['p50']:.1f} "
          f"p99={rep['queue_depth']['p99']:.1f} "
          f"max={rep['queue_depth']['max']:.0f}")

    # Exact counts (machine-independent: virtual clock + fixed model).
    for k, v in EXPECTED.items():
        assert s[k] == v, f"{k}: got {s[k]}, expected exactly {v}"
    # Conservation.
    assert s["offered"] == s["accepted"] + s["shed"]
    assert s["accepted"] == (s["completed"] + s["deadline_exceeded"]
                             + s["failed"])
    assert cbs.queue_depth() == 0, "drained replay left queued requests"
    # Deadline contract: nothing served late; completed p99 <= deadline.
    for r in cbs.requests.values():
        if r.status == "done" and r.deadline_t is not None:
            assert r.t_complete <= r.deadline_t + 1e-12, \
                f"request {r.idx} served {r.t_complete - r.deadline_t:.4f}s " \
                f"past its deadline without DeadlineExceeded"
        if r.status == "done":
            assert np.isfinite(np.asarray(r.y)).all(), \
                f"request {r.idx}: non-finite served output"
    assert rep["latency"]["p99"] <= DEADLINE_S, \
        f"completed p99 {rep['latency']['p99']:.4f}s > deadline {DEADLINE_S}s"
    print("exact counts, conservation, deadline contract, finiteness: OK")

    # Determinism: the identical schedule replays to the identical report.
    _, rep2 = run_once(server)
    assert rep2 == rep, "replay is not deterministic"
    print("replay determinism: OK")

    # Rollout trace contract: K-step rollout == num_layers pallas_calls
    # for K in {1, 4} (the acceptance-criteria pin), clean casts.
    from repro.analysis import format_findings
    from repro.analysis.jaxpr_lint import lint_rollout
    findings = lint_rollout(archs=("fno2d",), dtypes=("f32",), ks=(1, 4))
    assert not findings, format_findings(findings)
    print(f"rollout trace contract: {cfg.num_layers} pallas_calls for "
          f"K in (1, 4): OK")

    # Rollout parity through the tier: a K-step continuous-batched answer
    # matches the engine's own device-resident rollout bit-for-bit (the
    # tier only batches — it never changes math).
    x = np.asarray(jax.random.normal(jax.random.PRNGKey(1),
                                     (2, cfg.in_channels)
                                     + tuple(cfg.spatial)))
    direct = np.asarray(server(jnp.asarray(x),
                               rollout_steps=ROLLOUT_STEPS))
    cbs3 = sq.ContinuousBatchingServer(server, queue_limit=4)
    idx = cbs3.submit(x, rollout_steps=ROLLOUT_STEPS)
    cbs3.drain()
    got = np.asarray(cbs3.result(idx).y)
    assert np.array_equal(got, direct), "tier changed the rollout answer"
    print("tier-vs-engine rollout parity: OK")
    print("serve_replay_smoke: all gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
