#!/usr/bin/env python
"""Chaos gate (ISSUE 9, docs/DESIGN.md §9): a scripted deterministic fault
plan replayed through ``ResilientServer`` — CI fails unless the resilience
contract holds EXACTLY:

  * every accepted request is answered with a finite output (zero drops
    through a kernel fault, a NaN injection, and a replica kill);
  * degraded-request count == planned degradation faults (kernel + nan) —
    no silent fallback, no spurious fallback;
  * shed-request count == the admission overflow the script provokes;
  * every degraded (XLA-fallback) answer matches the staged XLA oracle to
    the tier-1 parity tolerance (2e-4), as do the healthy pallas answers;
  * a corrupted checkpoint makes the hot reload ROLL BACK (old params keep
    serving, bit-identical), and a subsequent valid checkpoint reloads.

Pure CPU: the pallas path runs in interpret mode; tiny reduced config.

Usage: PYTHONPATH=src python scripts/chaos_smoke.py
"""
import dataclasses
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

PARITY_TOL = 2e-4  # the tier-1 pallas-vs-oracle tolerance


def main() -> int:
    import jax
    import numpy as np

    from repro.checkpoint import Checkpointer
    from repro.configs import get_config
    from repro.core import fno as fno_mod
    from repro.distributed import faults as flt
    from repro.train import serve_runtime as srt

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    params2 = fno_mod.init_fno(jax.random.PRNGKey(1), cfg)

    plan = flt.standard_chaos_plan()
    n_requests = 4
    n_overflow = 2
    planned_degradations = plan.count(kinds=("kernel", "nan"))
    planned_kills = plan.count(kinds=("kill",))

    with tempfile.TemporaryDirectory() as ckdir:
        ck = Checkpointer(ckdir)
        rs = srt.ResilientServer(
            cfg, params, replicas=2, max_batch=2,
            queue_limit=n_requests, fault_plan=plan, checkpointer=ck,
            seed=0, backoff_base_s=1e-3)

        xs = [jax.random.normal(jax.random.fold_in(key, i),
                                (2, cfg.in_channels) + tuple(cfg.spatial))
              for i in range(n_requests)]
        oracle = [np.asarray(fno_mod.apply_fno(params, cfg, x, path="xla"))
                  for x in xs]

        # -- the fault-plan replay ------------------------------------------
        for x in xs:
            rs.submit(x)
        ys = rs.drain()

        assert len(ys) == n_requests, (
            f"dropped requests: {len(ys)}/{n_requests} answered")
        for i, y in enumerate(ys):
            assert np.isfinite(y).all(), f"request {i}: non-finite output"
            err = float(np.max(np.abs(y - oracle[i])))
            assert err <= PARITY_TOL, (
                f"request {i}: |y - oracle| = {err:.2e} > {PARITY_TOL}")
        s = rs.stats
        assert s["degraded"] == planned_degradations, (
            f"degraded={s['degraded']}, plan injected "
            f"{planned_degradations} degradation faults — the counter and "
            f"the plan must match exactly (no silent fallback)")
        assert s["killed"] == planned_kills and s["failovers"] >= 1, (
            f"killed={s['killed']} failovers={s['failovers']}: the replica "
            f"kill must cost a failover, not an answer")
        assert s["served"] == s["accepted"] == n_requests
        assert rs.pool.states()["dead"] == planned_kills

        # -- admission overflow: explicit shed, exact count -----------------
        shed = 0
        for i in range(n_requests + n_overflow):
            try:
                rs.submit(xs[i % n_requests])
            except srt.RequestRejected:
                shed += 1
        assert shed == n_overflow, (
            f"queue_limit={n_requests}: expected exactly {n_overflow} "
            f"shed, got {shed}")
        assert rs.stats["shed"] == n_overflow
        ys2 = rs.drain()
        assert len(ys2) == n_requests
        assert all(np.isfinite(y).all() for y in ys2)

        # -- corrupt checkpoint: reload rolls back, old params serve --------
        ck.save(1, params2)
        flt.corrupt_checkpoint(ckdir, 1)
        before = rs(xs[0])
        assert rs.reload() is False, "corrupt ckpt must roll back"
        assert rs.stats["rollbacks"] == 1
        after = rs(xs[0])
        np.testing.assert_array_equal(before, after)

        # -- valid checkpoint: canary passes, params swap -------------------
        ck.save(2, params2)
        assert rs.reload() is True, "valid ckpt must reload"
        y_new = rs(xs[0])
        want = np.asarray(fno_mod.apply_fno(params2, cfg, xs[0],
                                            path="xla"))
        assert float(np.max(np.abs(y_new - want))) <= PARITY_TOL

        print(f"chaos smoke OK: {s['accepted']} accepted requests all "
              f"finite under kernel+nan+kill faults "
              f"(degraded={s['degraded']} == plan, failovers="
              f"{s['failovers']}, shed={rs.stats['shed']} == overflow, "
              f"reload rollback+swap verified, parity <= {PARITY_TOL})")
        print(f"  final pool: {rs.pool.states()}  stats: {rs.stats}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
