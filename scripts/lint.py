#!/usr/bin/env python
"""Contract linter CLI (docs/DESIGN.md §7).

Sweeps the repo's machine-checked design contracts:

  --ast       source lints (compiler-params shim, compat_shard_map,
              no raw jnp.fft, dtype literals) — no jax, runs first
  --registry  config-registry audit (every seeded arch: runnable cell or
              non-empty skip reason)
  --vmem      static VMEM-footprint estimates for every engine launch
              across the FNO configs × dtypes × variants
  --trace     jaxpr trace lints: pallas_call counts, cast ownership, and
              collective budget over ranks 1-3 × weight layouts × fusion
              variants × f32/bf16 × DP/TP (needs the 8 virtual devices
              this script forces below)
  --tuning    tuned block-plan cache staleness/integrity: engine
              signature, VMEM budget, key schema, and a probe-shape
              refit of every committed winner (repro.tuning.store)
  --all       everything above (what scripts/check.sh and CI run)

Exit status is the number of error-severity findings (capped at 1);
warn-severity findings are printed but do not fail the lint.

Usage: PYTHONPATH=src python scripts/lint.py --all
"""
import argparse
import os
import sys

# Virtual devices for the DP/TP trace lints — MUST precede any jax import.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--all", action="store_true", help="run every lint")
    ap.add_argument("--ast", action="store_true")
    ap.add_argument("--registry", action="store_true")
    ap.add_argument("--vmem", action="store_true")
    ap.add_argument("--trace", action="store_true")
    ap.add_argument("--tuning", action="store_true")
    args = ap.parse_args()
    if not (args.all or args.ast or args.registry or args.vmem
            or args.trace or args.tuning):
        ap.error("pick at least one of --all/--ast/--registry/--vmem/"
                 "--trace/--tuning")

    from repro.analysis import errors, format_findings

    findings = []

    if args.all or args.ast:
        from repro.analysis import ast_lint
        fs = ast_lint.run_ast_lints()
        print(f"ast lints: {len(errors(fs))} error(s)")
        findings += fs

    if args.all or args.registry:
        from repro.analysis import ast_lint
        fs = ast_lint.check_config_registry()
        print(f"config-registry audit: {len(errors(fs))} error(s)")
        findings += fs

    if args.all or args.vmem:
        from repro.analysis import vmem
        fs = vmem.check_vmem()
        nw = sum(1 for f in fs if f.severity == "warn")
        print(f"vmem estimates: {len(errors(fs))} error(s), "
              f"{nw} warn(s)")
        findings += fs

    if args.all or args.tuning:
        from repro.tuning import check_tuning_cache
        fs = check_tuning_cache()
        print(f"tuning cache: {len(errors(fs))} error(s)")
        findings += fs

    if args.all or args.trace:
        from repro.analysis import jaxpr_lint
        for name, run in (
                ("block matrix", jaxpr_lint.lint_block_matrix),
                ("fused models", jaxpr_lint.lint_model),
                ("sharded blocks", jaxpr_lint.lint_sharded_blocks),
                ("serve steps", jaxpr_lint.lint_serve),
                ("rollout serve", jaxpr_lint.lint_rollout),
                ("resilient serve", jaxpr_lint.lint_resilient_serve)):
            fs = run()
            print(f"trace lints [{name}]: {len(errors(fs))} error(s)")
            findings += fs

    if findings:
        print(format_findings(findings))
    errs = errors(findings)
    print(f"contract lint: {len(errs)} error(s), "
          f"{len(findings) - len(errs)} warn(s)")
    return 1 if errs else 0


if __name__ == "__main__":
    sys.exit(main())
