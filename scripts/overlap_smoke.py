"""Comm/compute-overlap smoke (CI, scripts/check.sh — ISSUE 8).

The scattered TP layout's opt-in overlap mode (cfg.tp_overlap) replaces
each interior layer's one-shot psum_scatter with a ppermute ring
(distributed/sharding.ring_scatter_sum): tp-1 async chunk hops XLA can
hide under the neighboring layers' k-loop compute. Same math, same
sharding, same wire bytes — only the schedule changes. This smoke pins
that contract on a forced dp2×tp4 CPU mesh:

  * the ring forward matches the one-shot scattered forward (and the
    XLA oracle) to fused-kernel tolerance;
  * jax.grad flows through the ring natively (ppermute transposes to
    ppermute — no custom_vjp needed) and matches the one-shot grads;
  * the traced collective plan is exactly (tp-1) ppermutes per INTERIOR
    layer, ZERO reduce-scatters, and the final layer's single psum.

Pure CPU, seconds — the interpret-mode kernels execute on tiny reduced
shapes. The modeled wire-byte claim (0.5x per interior layer vs the psum
layout, unchanged by the ring) lives in roofline.analysis
.fno_collective_bytes and benchmarks/bench_e2e.run_serve.
"""
import os
import sys

# Virtual devices for the DP×TP mesh — MUST precede any jax import.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main() -> None:
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.analysis import jaxpr_lint as jl
    from repro.configs import get_config
    from repro.core import fno as fno_mod
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_compat_mesh

    dp, tp = 2, 4
    assert jax.device_count() >= dp * tp, (
        f"needs {dp * tp} devices, have {jax.device_count()} — run with "
        f"XLA_FLAGS=--xla_force_host_platform_device_count={dp * tp}")
    cfg0 = dataclasses.replace(get_config("fno2d", reduced=True),
                               path="pallas", fuse_block=True)
    L = cfg0.num_layers
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg0)
    x = jax.random.normal(key, (8, cfg0.in_channels) + tuple(cfg0.spatial))
    y_ref = fno_mod.apply_fno(params, cfg0, x, path="xla")
    mesh = make_compat_mesh((dp, tp), ("data", "model"))

    outs, grads, colls = {}, {}, {}
    for overlap in (False, True):
        cfg = dataclasses.replace(cfg0, tp_layout="scatter",
                                  tp_overlap=overlap)
        ctx = shd.make_context(cfg, mesh, kind="serve")
        assert ctx.model_axis == "model", ctx

        # fresh closures per variant: jax.make_jaxpr caches on function
        # identity + avals and cannot see the thread-local context
        def fwd(p, xx, _cfg=cfg, _ctx=ctx):
            with shd.sharding_context(_ctx):
                return fno_mod.apply_fno(p, _cfg, xx, path="pallas")

        name = "ring" if overlap else "oneshot"
        outs[name] = jax.jit(fwd)(params, x)
        grads[name] = jax.jit(jax.grad(
            lambda p, xx, _f=fwd: jnp.sum(_f(p, xx) ** 2)))(params, x)
        colls[name] = jl.collective_counts(fwd, params, x)

    err_ref = float(jnp.abs(outs["ring"] - y_ref).max())
    err_one = float(jnp.abs(outs["ring"] - outs["oneshot"]).max())
    assert err_ref < 2e-4, f"ring vs XLA oracle: {err_ref}"
    assert err_one < 1e-5, f"ring vs one-shot scatter: {err_one}"
    gerr = max(float(jnp.abs(a - b).max()) for a, b in zip(
        jax.tree_util.tree_leaves(grads["ring"]),
        jax.tree_util.tree_leaves(grads["oneshot"])))
    assert gerr < 1e-4, f"ring grads vs one-shot: {gerr}"

    one = colls["oneshot"]
    ring = colls["ring"]
    rs = one.get("reduce_scatter", 0) + one.get("psum_scatter", 0)
    assert rs == L - 1 and one.get("psum", 0) == 1, one
    assert ring.get("ppermute", 0) == (tp - 1) * (L - 1), ring
    assert ring.get("reduce_scatter", 0) == 0 and \
        ring.get("psum_scatter", 0) == 0, ring
    assert ring.get("psum", 0) == 1, ring

    print(f"overlap smoke OK: dp{dp}xtp{tp}, ring=ppermute x "
          f"{(tp - 1) * (L - 1)} (interior) + 1 final psum, "
          f"fwd_err={err_one:.2e} grad_err={gerr:.2e}")


if __name__ == "__main__":
    main()
