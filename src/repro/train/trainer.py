"""Training loop with checkpoint/restart, failure injection, and straggler
monitoring — the fault-tolerance glue (docs/DESIGN.md §6).

The loop is restart-idempotent: state = (params, opt_state) in the
checkpoint; the data pipeline is stateless (batch = f(seed, step)), so a
restart at step k replays nothing and skips nothing.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import Checkpointer
from repro.data.pipeline import PrefetchPipeline
from repro.distributed.fault_tolerance import StragglerMonitor, Watchdog


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True
    log_every: int = 10
    step_timeout_s: float = 0.0  # 0 = watchdog off
    prefetch_depth: int = 2
    data_timeout_s: Optional[float] = None


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 batch_fn: Callable[[int], Dict], params: Any,
                 opt_state: Any,
                 fail_at: Optional[Dict[int, Exception]] = None):
        self.cfg = cfg
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.params = params
        self.opt_state = opt_state
        self.ckpt = Checkpointer(cfg.ckpt_dir)
        self.monitor = StragglerMonitor()
        self.metrics_log: List[Dict] = []
        self.restarts = 0
        self._fail_at = fail_at or {}  # step -> exception (failure injection)

    # ------------------------------------------------------------------
    def _restore_if_any(self) -> int:
        step = self.ckpt.latest_step()
        if step is None:
            return 0
        state = self.ckpt.restore(
            step, {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = state["params"], state["opt"]
        return step

    def run(self) -> Dict[str, Any]:
        start = self._restore_if_any()
        pipe = PrefetchPipeline(self.batch_fn, start_index=start,
                                depth=self.cfg.prefetch_depth)
        wd = None
        if self.cfg.step_timeout_s > 0:
            wd = Watchdog(self.cfg.step_timeout_s, lambda: None)
        step = start
        try:
            while step < self.cfg.total_steps:
                t0 = time.monotonic()
                _, batch = pipe.get(timeout=self.cfg.data_timeout_s)
                if step in self._fail_at:  # injected failure
                    exc = self._fail_at.pop(step)
                    raise exc
                self.params, self.opt_state, m = self.train_step(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(m["loss"])
                dt = time.monotonic() - t0
                self.monitor.record(step, dt)
                if wd:
                    wd.beat()
                if step % self.cfg.log_every == 0:
                    self.metrics_log.append(
                        {"step": step, "loss": float(m["loss"]),
                         "grad_norm": float(m["grad_norm"]), "dt": dt})
                step += 1
                if step % self.cfg.ckpt_every == 0 or \
                        step == self.cfg.total_steps:
                    self.ckpt.save(
                        step, {"params": self.params, "opt": self.opt_state},
                        blocking=not self.cfg.ckpt_async)
        finally:
            pipe.stop()
            if wd:
                wd.stop()
            self.ckpt.wait()
        return {"final_step": step, "metrics": self.metrics_log,
                "stragglers": self.monitor.flagged,
                "skipped_batches": pipe.skipped}

    # ------------------------------------------------------------------
    def run_with_restarts(self, max_restarts: int = 3) -> Dict[str, Any]:
        """Run to completion, restarting from the last checkpoint on any
        failure (the single-host analogue of scheduler-level restart)."""
        while True:
            try:
                return self.run()
            except Exception:  # noqa: BLE001
                self.restarts += 1
                if self.restarts > max_restarts:
                    raise
                self._restore_if_any()
