"""Training loop with checkpoint/restart, failure injection, and straggler
monitoring — the fault-tolerance glue (docs/DESIGN.md §6, §9).

The loop is restart-idempotent: state = (params, opt_state) in the
checkpoint; the data pipeline is stateless (batch = f(seed, step)), so a
restart at step k replays nothing and skips nothing. On top of that
(ISSUE 9) the loop is *fault-absorbing*: a fired watchdog raises
``WatchdogTimeout`` into the restart path (no more no-op callback), a
non-finite loss/grad_norm discards the poisoned update under a bounded
skip budget, checkpoint saves retry with exponential backoff, and restore
goes through ``Checkpointer.latest_valid_step`` so a corrupt checkpoint
is skipped instead of fatal. Deterministic faults are injected through
the explicit ``distributed.faults.FaultPlan`` hooks (scope="train").
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.checkpoint import Checkpointer
from repro.data.pipeline import PrefetchPipeline
from repro.distributed import faults as flt
from repro.distributed.fault_tolerance import StragglerMonitor, Watchdog


class WatchdogTimeout(RuntimeError):
    """A training step exceeded ``step_timeout_s`` — raised into the loop
    so ``run_with_restarts`` restores from the last valid checkpoint (the
    single-host analogue of the coordinator evicting a stuck host)."""


class NaNBudgetExceeded(RuntimeError):
    """More than ``nan_skip_budget`` non-finite steps — the poisoning is
    persistent, so restarting would replay it; surface instead."""


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int
    ckpt_every: int = 50
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_async: bool = True
    log_every: int = 10
    step_timeout_s: float = 0.0  # 0 = watchdog off
    prefetch_depth: int = 2
    data_timeout_s: Optional[float] = None
    # Resilience knobs (ISSUE 9):
    nan_skip_budget: int = 3     # non-finite steps absorbed before raising
    ckpt_retries: int = 2        # extra save attempts after a failure
    ckpt_backoff_s: float = 0.05  # first retry delay (doubles per attempt)


class Trainer:
    def __init__(self, cfg: TrainerConfig, train_step: Callable,
                 batch_fn: Callable[[int], Dict], params: Any,
                 opt_state: Any,
                 fail_at: Optional[Dict[int, Exception]] = None,
                 fault_plan: Optional[flt.FaultPlan] = None):
        self.cfg = cfg
        self.train_step = train_step
        self.batch_fn = batch_fn
        self.params = params
        self.opt_state = opt_state
        self.ckpt = Checkpointer(cfg.ckpt_dir)
        self.monitor = StragglerMonitor()
        self.metrics_log: List[Dict] = []
        self.restarts = 0
        self.nan_skipped = 0
        self.ckpt_save_retries = 0
        self._fail_at = fail_at or {}  # step -> exception (failure injection)
        self._plan = fault_plan
        self._watchdog_stall = 0.0  # set by the watchdog thread

    # ------------------------------------------------------------------
    def _restore_if_any(self) -> int:
        # latest_valid_step: a checkpoint corrupted by a crash mid-GC or
        # bad disk is skipped in favor of the newest one that verifies.
        step = self.ckpt.latest_valid_step()
        if step is None:
            return 0
        state = self.ckpt.restore(
            step, {"params": self.params, "opt": self.opt_state})
        self.params, self.opt_state = state["params"], state["opt"]
        return step

    def _on_watchdog(self) -> None:
        # Runs on the watchdog thread: record the stall; the loop raises
        # WatchdogTimeout from its own thread at the next boundary so the
        # restart unwinds through the normal exception path.
        self._watchdog_stall = time.monotonic()

    def _check_watchdog(self) -> None:
        if self._watchdog_stall:
            self._watchdog_stall = 0.0
            raise WatchdogTimeout(
                f"training step exceeded {self.cfg.step_timeout_s}s — "
                f"restarting from the last valid checkpoint")

    def _save_ckpt(self, step: int) -> None:
        """Checkpoint save with bounded retry + exponential backoff: a
        transient I/O failure (injected via FaultPlan kind="ckpt_io", or
        a real flaky filesystem) costs a retry, not the run."""
        delay = self.cfg.ckpt_backoff_s
        for attempt in range(self.cfg.ckpt_retries + 1):
            try:
                if self._plan and self._plan.take("train", step,
                                                 kind="ckpt_io"):
                    raise IOError(
                        f"injected checkpoint I/O fault at step {step}")
                self.ckpt.save(
                    step, {"params": self.params, "opt": self.opt_state},
                    blocking=not self.cfg.ckpt_async)
                return
            except Exception:
                if attempt == self.cfg.ckpt_retries:
                    raise
                self.ckpt_save_retries += 1
                time.sleep(delay)
                delay *= 2

    def run(self) -> Dict[str, Any]:
        self._watchdog_stall = 0.0  # a stale stall must not fail a restart
        start = self._restore_if_any()
        pipe = PrefetchPipeline(self.batch_fn, start_index=start,
                                depth=self.cfg.prefetch_depth)
        wd = None
        if self.cfg.step_timeout_s > 0:
            wd = Watchdog(self.cfg.step_timeout_s, self._on_watchdog)
        step = start
        try:
            while step < self.cfg.total_steps:
                t0 = time.monotonic()
                _, batch = pipe.get(timeout=self.cfg.data_timeout_s)
                self._check_watchdog()
                if self._plan:
                    for f in self._plan.take("train", step, kind="delay"):
                        time.sleep(f.delay_s)
                    if self._plan.take("train", step, kind="nan"):
                        batch = flt.poison_batch(batch)
                if step in self._fail_at:  # injected failure
                    exc = self._fail_at.pop(step)
                    raise exc
                new_params, new_opt, m = self.train_step(
                    self.params, self.opt_state, batch)
                jax.block_until_ready(m["loss"])
                loss = float(m["loss"])
                grad_norm = float(m["grad_norm"])
                self._check_watchdog()
                if not (math.isfinite(loss) and math.isfinite(grad_norm)):
                    # Non-finite guard: discard the poisoned update (the
                    # master params/opt_state are untouched) under a
                    # bounded budget — silent NaN laundering into the
                    # weights is the one unrecoverable failure.
                    self.nan_skipped += 1
                    if self.nan_skipped > self.cfg.nan_skip_budget:
                        raise NaNBudgetExceeded(
                            f"{self.nan_skipped} non-finite steps exceed "
                            f"the skip budget "
                            f"({self.cfg.nan_skip_budget}) — loss/grad "
                            f"poisoning is persistent, not transient")
                    if wd:
                        wd.beat()
                    step += 1
                    continue
                self.params, self.opt_state = new_params, new_opt
                dt = time.monotonic() - t0
                self.monitor.record(step, dt)
                if wd:
                    wd.beat()
                if step % self.cfg.log_every == 0:
                    self.metrics_log.append(
                        {"step": step, "loss": loss,
                         "grad_norm": grad_norm, "dt": dt})
                step += 1
                if step % self.cfg.ckpt_every == 0 or \
                        step == self.cfg.total_steps:
                    self._save_ckpt(step)
        finally:
            pipe.stop()
            if wd:
                wd.stop()
            self.ckpt.wait()
        return {"final_step": step, "metrics": self.metrics_log,
                "stragglers": self.monitor.flagged,
                "skipped_batches": pipe.skipped,
                "nan_skipped": self.nan_skipped,
                "ckpt_save_retries": self.ckpt_save_retries}

    # ------------------------------------------------------------------
    def run_with_restarts(self, max_restarts: int = 3) -> Dict[str, Any]:
        """Run to completion, restarting from the last valid checkpoint on
        any failure (the single-host analogue of scheduler-level restart).
        ``NaNBudgetExceeded`` is deliberately NOT restartable: the data is
        deterministic in (seed, step), so a replay would re-poison."""
        while True:
            try:
                return self.run()
            except NaNBudgetExceeded:
                raise
            except Exception:  # noqa: BLE001
                self.restarts += 1
                if self.restarts > max_restarts:
                    raise
                self.monitor.reset()  # post-restart EMA must start fresh
                self._restore_if_any()
