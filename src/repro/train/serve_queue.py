"""Async continuous-batching serving tier (docs/DESIGN.md §10).

``FNOServer`` answers one request batch at a time; a real front end sees
many small concurrent per-user requests. ``ContinuousBatchingServer``
sits on top of a (resilient) server and coalesces those requests into
kernel-block-sized buckets — the SAME bucket ladder the engine serves
(``serve_fno_step.bucket_sizes`` over the tuned-plan quantum) — with:

  * **bounded admission** — ``submit`` sheds with ``RequestRejected``
    once ``queue_limit`` requests are pending; every shed is counted.
  * **per-request timestamps** — enqueue → dispatch → complete, so p50/
    p99 latency and queue-depth accounting fall out of the request
    records instead of external profiling.
  * **deadline-aware batch formation** — the queue may hold a non-full
    bucket for up to ``coalesce_s`` to admit more requests, but NEVER
    past the point where any queued request's deadline could no longer
    be met; a request whose deadline cannot be met at dispatch time is
    failed with ``DeadlineExceeded``, never served late silently.
  * **rollout batching** — requests carry ``rollout_steps``; a batch is
    formed only within one rollout depth (the scan length is a static
    jit argument), FIFO within the bucket.

Determinism: "async" here is a cooperative event loop, not threads —
the same single-host-determinism idiom as the replica pool in
``serve_runtime``. The clock is injectable: ``replay`` drives the whole
tier on a ``VirtualClock`` with a deterministic ``service_model``
((bucket, rollout_steps) -> seconds), so a seeded arrival schedule
(``poisson_schedule`` — no wall-clock randomness) yields EXACT shed/
coalesce counts and reproducible p50/p99 rows while every formed batch
still executes for real (outputs stay finiteness-checkable). On a live
deployment the clock is ``time.monotonic`` and submit/pump run from the
request handler.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Any, Callable, Deque, Dict, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.train.serve_fno_step import pick_bucket
from repro.train.serve_runtime import DeadlineExceeded, RequestRejected

QUEUE_STATS = ("offered", "accepted", "shed", "completed",
               "deadline_exceeded", "failed", "batches", "coalesced")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One scheduled request of a traffic replay: ``n`` samples arriving
    at time ``t`` (seconds on the replay clock), asking for a
    ``rollout_steps``-deep trajectory within ``deadline_s``."""

    t: float
    n: int
    rollout_steps: int = 1
    deadline_s: Optional[float] = None


@dataclasses.dataclass
class QueuedRequest:
    """One admitted request and its full lifecycle record."""

    idx: int
    n: int
    x: Any
    rollout_steps: int = 1
    deadline_t: Optional[float] = None  # absolute, on the server's clock
    t_enqueue: float = 0.0
    t_dispatch: Optional[float] = None
    t_complete: Optional[float] = None
    status: str = "queued"  # queued | done | deadline | failed
    y: Any = None
    error: Optional[str] = None

    @property
    def latency_s(self) -> Optional[float]:
        if self.t_complete is None:
            return None
        return self.t_complete - self.t_enqueue


class VirtualClock:
    """Monotonic virtual time for deterministic replays: ``now`` reads
    it, the event loop advances it — wall time never enters."""

    def __init__(self, t0: float = 0.0):
        self._t = float(t0)

    def now(self) -> float:
        return self._t

    def advance_to(self, t: float) -> float:
        self._t = max(self._t, float(t))
        return self._t


def poisson_schedule(seed: int, requests: int, *, rate_hz: float,
                     max_n: int, rollout_steps: int = 1,
                     deadline_s: Optional[float] = None,
                     rollout_choices: Optional[Sequence[int]] = None
                     ) -> List[Arrival]:
    """Seeded Poisson-ish arrival schedule: exponential inter-arrival
    times at ``rate_hz``, request sizes uniform on [1, max_n]. A pure
    function of the seed — no wall-clock randomness, so every replay of
    the same schedule produces the same admission/coalescing decisions.
    ``rollout_choices`` mixes rollout depths across requests (uniform)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_hz, size=requests)
    ts = np.cumsum(gaps)
    ns = rng.integers(1, max_n + 1, size=requests)
    if rollout_choices:
        steps = rng.choice(np.asarray(rollout_choices), size=requests)
    else:
        steps = np.full(requests, rollout_steps)
    return [Arrival(float(t), int(n), int(k), deadline_s)
            for t, n, k in zip(ts, ns, steps)]


class ContinuousBatchingServer:
    """Coalescing request queue over a batched (resilient) server.

    ``server`` is any callable ``server(x, rollout_steps=k) -> y`` over
    ``[n, C, *spatial]`` batches — an ``FNOServer``, a
    ``ResilientServer``, or a test double. ``buckets`` defaults to the
    server's own ladder (``server.buckets``, or ``server.primary.buckets``
    for the resilient runtime) so the queue coalesces to exactly the
    batch shapes the engine's jit cache already holds.

    Batch-formation policy (docs/DESIGN.md §10): the FIFO prefix of the
    queue sharing the head request's ``rollout_steps``, cut off at the
    largest bucket (a single oversize request rides alone — the engine
    chunks it). With a ``service_model`` the tier is deadline-aware at
    formation time: members whose deadline precedes the batch's modeled
    completion are failed with ``DeadlineExceeded`` instead of served
    late; without a model (live mode) the check degrades to
    already-expired-at-dispatch.
    """

    def __init__(self, server, *, buckets: Optional[Sequence[int]] = None,
                 queue_limit: int = 64, coalesce_s: float = 0.0,
                 clock=None,
                 service_model: Optional[Callable[[int, int], float]] = None):
        self._server = server
        if buckets is None:
            inner = getattr(server, "buckets", None)
            if inner is None:
                inner = getattr(getattr(server, "primary", None),
                                "buckets", None)
            if inner is None:
                raise ValueError(
                    "ContinuousBatchingServer: pass buckets= explicitly — "
                    "the server exposes no bucket ladder")
            buckets = inner
        self.buckets: Tuple[int, ...] = tuple(buckets)
        self.queue_limit = queue_limit
        self.coalesce_s = coalesce_s
        self.clock = clock if clock is not None else time.monotonic
        self._now = (self.clock.now if isinstance(self.clock, VirtualClock)
                     else self.clock)
        self.service_model = service_model
        self._queue: Deque[QueuedRequest] = collections.deque()
        self.requests: Dict[int, QueuedRequest] = {}
        self._next_idx = 0
        self.stats: Dict[str, int] = {k: 0 for k in QUEUE_STATS}
        self.depth_trace: List[Tuple[float, int]] = []

    # -- admission ----------------------------------------------------------
    def submit(self, x, *, rollout_steps: int = 1,
               deadline_s: Optional[float] = None) -> int:
        """Admit one request of ``x.shape[0]`` samples; returns its
        request index. Sheds with ``RequestRejected`` when ``queue_limit``
        requests are already pending."""
        now = self._now()
        self.stats["offered"] += 1
        if len(self._queue) >= self.queue_limit:
            self.stats["shed"] += 1
            raise RequestRejected(
                f"continuous-batching queue full ({self.queue_limit} "
                f"pending) — request shed")
        r = QueuedRequest(
            idx=self._next_idx, n=int(x.shape[0]), x=x,
            rollout_steps=int(rollout_steps), t_enqueue=now,
            deadline_t=None if deadline_s is None else now + deadline_s)
        self._next_idx += 1
        self._queue.append(r)
        self.requests[r.idx] = r
        self.stats["accepted"] += 1
        self._sample_depth(now)
        return r.idx

    def result(self, idx: int) -> QueuedRequest:
        return self.requests[idx]

    def queue_depth(self) -> int:
        return len(self._queue)

    def _sample_depth(self, t: float) -> None:
        self.depth_trace.append((t, len(self._queue)))

    # -- batch formation ----------------------------------------------------
    def _head_group(self) -> List[QueuedRequest]:
        """FIFO prefix sharing the head's rollout depth, cut at the
        largest bucket (the head alone may exceed it — the engine
        chunks)."""
        if not self._queue:
            return []
        top = self.buckets[-1]
        steps = self._queue[0].rollout_steps
        group, total = [], 0
        for r in self._queue:
            if r.rollout_steps != steps:
                break
            if group and total + r.n > top:
                break
            group.append(r)
            total += r.n
        return group

    def _service_est(self, total_n: int, steps: int) -> float:
        """Modeled service seconds for ``total_n`` samples (chunked at
        the largest bucket exactly as the engine will). 0.0 without a
        service model — live mode measures instead of predicting."""
        if self.service_model is None:
            return 0.0
        top = self.buckets[-1]
        est, left = 0.0, total_n
        while left > 0:
            chunk = min(left, top)
            est += self.service_model(pick_bucket(chunk, self.buckets),
                                      steps)
            left -= chunk
        return est

    # -- dispatch -----------------------------------------------------------
    def _dispatch(self, now: float) -> Tuple[List[QueuedRequest], float]:
        """Form and serve one batch at time ``now``. Returns (handled
        requests, engine-free time). Deadline-doomed members are failed
        (``DeadlineExceeded``) instead of served late."""
        group = self._head_group()
        if not group:
            return [], now
        steps = group[0].rollout_steps
        est = self._service_est(sum(r.n for r in group), steps)
        keep: List[QueuedRequest] = []
        handled: List[QueuedRequest] = []
        for r in group:
            self._queue.remove(r)
            doomed = (r.deadline_t is not None
                      and r.deadline_t < now + est)
            if doomed:
                r.status, r.t_complete = "deadline", now
                r.error = (f"request {r.idx} deadline at "
                           f"t={r.deadline_t:.4f}s unreachable from "
                           f"dispatch t={now:.4f}s (+{est:.4f}s service)")
                self.stats["deadline_exceeded"] += 1
                handled.append(r)
            else:
                keep.append(r)
        if not keep:
            self._sample_depth(now)
            return handled, now
        # Re-estimate on the survivors: dropping members can only shrink
        # the batch, so every kept deadline stays reachable.
        est = self._service_est(sum(r.n for r in keep), steps)
        for r in keep:
            r.t_dispatch = now
        x = np.concatenate([np.asarray(r.x) for r in keep], axis=0)
        self.stats["batches"] += 1
        self.stats["coalesced"] += len(keep) - 1
        try:
            y = np.asarray(self._server(x, rollout_steps=steps))
        except Exception as e:  # noqa: BLE001 — the tier records, not raises
            t_done = now + est if self.service_model else self._now()
            for r in keep:
                r.status, r.t_complete, r.error = "failed", t_done, str(e)
                self.stats["failed"] += 1
            self._sample_depth(t_done)
            return handled + keep, t_done
        t_done = now + est if self.service_model else self._now()
        off = 0
        for r in keep:
            r.y = y[off:off + r.n]
            off += r.n
            r.status, r.t_complete = "done", t_done
            self.stats["completed"] += 1
        handled += keep
        self._sample_depth(t_done)
        return handled, t_done

    def pump(self) -> List[QueuedRequest]:
        """Serve one batch if any work is queued (live-mode heartbeat)."""
        return self._dispatch(self._now())[0]

    def drain(self) -> List[QueuedRequest]:
        """Serve until the queue is empty; returns every handled
        request. After a drain the conservation invariant holds:
        accepted == completed + deadline_exceeded + failed."""
        out: List[QueuedRequest] = []
        while self._queue:
            out += self._dispatch(self._now())[0]
        return out

    # -- deterministic traffic replay --------------------------------------
    def replay(self, schedule: Sequence[Arrival],
               input_fn: Callable[[Arrival, int], Any]) -> Dict[str, Any]:
        """Drive the whole tier through a seeded arrival schedule on the
        virtual clock. ``input_fn(arrival, index) -> x`` materializes each
        request's samples (seed it — the replay adds no randomness).

        Event loop: requests arriving while the engine is busy coalesce;
        when the engine frees, the head group dispatches unless holding
        for the next arrival both fits ``coalesce_s`` AND keeps every
        queued deadline reachable (the don't-coalesce-past-a-deadline
        rule). Requires a ``VirtualClock`` and a ``service_model``."""
        if not isinstance(self.clock, VirtualClock):
            raise ValueError("replay() needs clock=VirtualClock(...)")
        if self.service_model is None:
            raise ValueError("replay() needs a deterministic service_model")
        order = sorted(range(len(schedule)), key=lambda i: schedule[i].t)
        seq = [schedule[i] for i in order]
        i, engine_free = 0, 0.0

        def admit(k: int) -> None:
            a = seq[k]
            self.clock.advance_to(a.t)
            try:
                self.submit(input_fn(a, k), rollout_steps=a.rollout_steps,
                            deadline_s=a.deadline_s)
            except RequestRejected:
                pass  # counted in stats["shed"]

        while i < len(seq) or self._queue:
            if not self._queue:
                admit(i)
                i += 1
                continue
            t_ready = max(self.clock.now(), engine_free)
            # Arrivals landing while the engine is busy join the queue.
            while i < len(seq) and seq[i].t <= t_ready:
                admit(i)
                i += 1
            group = self._head_group()
            total = sum(r.n for r in group)
            if total < self.buckets[-1] and i < len(seq):
                hold = t_ready + self.coalesce_s
                est = self._service_est(total, group[0].rollout_steps)
                dls = [r.deadline_t for r in group
                       if r.deadline_t is not None]
                if dls:
                    hold = min(hold, min(dls) - est)
                if seq[i].t <= hold:
                    admit(i)
                    i += 1
                    continue
            self.clock.advance_to(t_ready)
            _, engine_free = self._dispatch(t_ready)
        return self.report()

    # -- accounting ---------------------------------------------------------
    def latency_summary(self) -> Dict[str, float]:
        """p50/p99/mean/max enqueue→complete latency (seconds) over the
        COMPLETED requests (shed and deadline-failed requests have no
        service latency; they are accounted in stats)."""
        lats = [r.latency_s for r in self.requests.values()
                if r.status == "done"]
        if not lats:
            return {"p50": 0.0, "p99": 0.0, "mean": 0.0, "max": 0.0,
                    "count": 0}
        arr = np.asarray(lats, np.float64)
        return {"p50": float(np.percentile(arr, 50)),
                "p99": float(np.percentile(arr, 99)),
                "mean": float(arr.mean()), "max": float(arr.max()),
                "count": int(arr.size)}

    def depth_summary(self) -> Dict[str, float]:
        """p50/p99/max queue depth over the event-sampled depth trace."""
        if not self.depth_trace:
            return {"p50": 0.0, "p99": 0.0, "max": 0.0, "samples": 0}
        d = np.asarray([n for _, n in self.depth_trace], np.float64)
        return {"p50": float(np.percentile(d, 50)),
                "p99": float(np.percentile(d, 99)),
                "max": float(d.max()), "samples": int(d.size)}

    def report(self) -> Dict[str, Any]:
        """Stats + latency + queue-depth in one dict (what the replay
        benchmark rows and the smoke gate read)."""
        done = [r for r in self.requests.values() if r.status == "done"]
        samples = sum(r.n for r in done)
        span = (max(r.t_complete for r in done)
                - min(r.t_enqueue for r in done)) if done else 0.0
        return {"stats": dict(self.stats),
                "latency": self.latency_summary(),
                "queue_depth": self.depth_summary(),
                "served_samples": samples,
                "makespan_s": float(span)}
