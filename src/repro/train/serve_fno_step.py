"""Batched FNO serving: the forward step, the device-resident rollout
step, request bucketing, and a jit-cached server for the fused pallas
path (docs/DESIGN.md §6, §10).

FNO inference has no KV cache, so single-step serving reduces to (1)
batching requests, (2) padding each batch to a BUCKET size so the jit
cache stays finite and the fused kernel's grid never re-specializes, and
(3) running the bucketed forward on a DP×TP mesh. Buckets are multiples
of the fused engine's tuned batch block (``repro.tuning.serve_quantum``,
which validates the ladder against the autotuned cache) times the DP
shard count, so neither the kernel nor the mesh ever sees a ragged batch.

The production workload IS autoregressive, though: a PDE rollout feeds
step t's prediction back as step t+1's state. ``make_fno_rollout_step``
keeps the whole K-step trajectory device-resident inside one jitted
``lax.scan`` — the scan body traces once, so the trace stays exactly
``num_layers`` pallas_calls regardless of rollout depth (docs/DESIGN.md
§10; pinned by ``analysis.jaxpr_lint.lint_rollout``).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FNOConfig
from repro.core import fno as fno_mod
from repro.distributed import sharding as shd
from repro.tuning import resolve_block_plan, serve_quantum


def make_fno_serve_step(cfg: FNOConfig, *, path: Optional[str] = None,
                        variant: str = "full"):
    """serve_step(params, batch{"x": [B,C_in,*spatial]}) -> y.

    One batched forward at ``cfg.precision``; ``path`` defaults to
    ``cfg.path`` (the production cells set "pallas" + ``cfg.fuse_block``).
    Run it inside a ``sharding_context`` for the DP×TP placement.
    """
    def fno_serve_step(params, batch: Dict[str, jax.Array]) -> jax.Array:
        return fno_mod.apply_fno(params, cfg, batch["x"],
                                 path=path or cfg.path, variant=variant)
    return fno_serve_step


def make_fno_rollout_step(cfg: FNOConfig, *, path: Optional[str] = None,
                          variant: str = "full"):
    """rollout(params, batch{"x": [B,C_in,*spatial]}, steps=K) -> y_K.

    Device-resident autoregressive rollout: step t+1 consumes step t's
    output inside ONE jitted ``lax.scan`` — the carry never leaves HBM
    between steps, so the fused kernels' traffic win compounds over the
    whole trajectory instead of being paid back to HBM every step.

    Channel feedback: the model maps ``in_channels -> out_channels``.
    When they match the carry is simply the output; when the input has
    extra conditioning channels (fno2d serves ``(a, x, y) -> u``) the
    first ``out_channels`` carry channels are replaced by the prediction
    and the trailing ``in_channels - out_channels`` channels (coordinate
    grids / static conditioning) persist across steps. Requires
    ``out_channels <= in_channels``.

    Trace contract: the scan body traces ONCE, so a K-step rollout on the
    fused pallas path contains exactly ``num_layers`` pallas_calls for
    ANY K (pinned by ``analysis.jaxpr_lint.lint_rollout``). ``steps``
    must be static under jit (``static_argnames=("steps",)``).
    """
    if cfg.out_channels > cfg.in_channels:
        raise ValueError(
            f"rollout needs out_channels <= in_channels to feed step t's "
            f"output back as step t+1's state, got {cfg.out_channels} > "
            f"{cfg.in_channels} for {cfg.name}")
    keep = cfg.in_channels - cfg.out_channels

    def fno_rollout_step(params, batch: Dict[str, jax.Array], *,
                         steps: int) -> jax.Array:
        # Cast ONCE so the scan carry dtype is invariant (apply_fno's own
        # input cast becomes the identity on every step).
        x0 = batch["x"].astype(jnp.dtype(cfg.precision.compute_dtype))

        def body(x, _):
            y = fno_mod.apply_fno(params, cfg, x, path=path or cfg.path,
                                  variant=variant)
            nxt = (jnp.concatenate([y, x[:, cfg.out_channels:]], axis=1)
                   if keep else y)
            return nxt, None

        xk, _ = jax.lax.scan(body, x0, None, length=steps)
        return xk[:, :cfg.out_channels]
    return fno_rollout_step


def batch_block(cfg: FNOConfig) -> int:
    """The fused engine's batch block (bb) for this workload — the
    serving quantum, so the kernel grid never pads the batch internally.
    Resolved through the tuned-plan cache (override → cache → static
    defaults), same as the kernel launch itself will."""
    return resolve_block_plan(cfg, "block_fwd").bb


def bucket_sizes(max_batch: int, *, quantum: int = 1) -> Tuple[int, ...]:
    """Geometric bucket ladder (quantum, 2q, 4q, … ≥ max_batch): one jit
    cache entry per bucket, log2(max/quantum)+1 compiles total."""
    q = max(quantum, 1)
    sizes = [q]
    while sizes[-1] < max_batch:
        sizes.append(sizes[-1] * 2)
    return tuple(sizes)


def pick_bucket(n: int, buckets: Sequence[int]) -> int:
    """Smallest bucket ≥ n (the largest bucket for oversize batches — the
    caller chunks those)."""
    for b in buckets:
        if b >= n:
            return b
    return buckets[-1]


def pad_to_bucket(x: jax.Array, bucket: int) -> Tuple[jax.Array, int]:
    """Zero-pad the batch axis to `bucket`; returns (padded, n_valid)."""
    n = x.shape[0]
    if n == bucket:
        return x, n
    pad = [(0, bucket - n)] + [(0, 0)] * (x.ndim - 1)
    return jnp.pad(x, pad), n


class FNOServer:
    """Request-batched FNO inference on the fused pallas path.

    Pads every request batch to a bucket (``bucket_sizes``), keeps one jit
    cache entry per bucket, and — given a ``ShardingContext`` — traces the
    step inside it so the forward runs DP over the batch axes and TP over
    the hidden axis (the shard_map dispatch in ``kernels.ops``). The
    un-jitted ``step_fn`` is exposed for trace-level guards
    (``roofline.hlo_counter.count_pallas_calls``).
    """

    def __init__(self, cfg: FNOConfig, params, *,
                 ctx: Optional[shd.ShardingContext] = None,
                 path: Optional[str] = None, variant: str = "full",
                 max_batch: int = 64, quantum: Optional[int] = None):
        self.cfg, self.params, self.ctx = cfg, params, ctx
        # The quantum is validated against the TUNED plan's batch block
        # (serve_quantum): an explicit quantum that is not a multiple of
        # the tuned bb would misalign the whole ladder with the kernel
        # grid — a retune can therefore never silently break it.
        q = serve_quantum(cfg, quantum)
        if ctx is not None:
            for a in ctx.batch_axes:  # buckets must split across DP shards
                q *= ctx.mesh.shape.get(a, 1)
        self.buckets = bucket_sizes(max_batch, quantum=q)
        base = make_fno_serve_step(cfg, path=path, variant=variant)
        roll = make_fno_rollout_step(cfg, path=path, variant=variant)
        if ctx is not None:
            def step_fn(params, batch):
                with shd.sharding_context(ctx):
                    return base(params, batch)

            def rollout_step_fn(params, batch, *, steps):
                with shd.sharding_context(ctx):
                    return roll(params, batch, steps=steps)
        else:
            step_fn, rollout_step_fn = base, roll
        self.step_fn = step_fn
        # Un-jitted, exposed for trace guards: a K-step rollout must trace
        # exactly num_layers pallas_calls regardless of K (lint_rollout).
        self.rollout_step_fn = rollout_step_fn
        self._step = jax.jit(step_fn)
        self._rollout = jax.jit(rollout_step_fn, static_argnames=("steps",))
        self.stats = {"requests": 0, "samples": 0, "padded": 0}

    def collective_plan(self) -> Dict[str, object]:
        """The serving step's TP collective plan as metadata (ISSUE 8) —
        what the serve driver prints and ops dashboards scrape: the
        layout, whether the interior reduce-scatter runs as the ppermute
        ring (cfg.tp_overlap), the per-layer collective kinds, and the
        modeled per-device ICI wire bytes per forward at the SMALLEST
        bucket (``roofline.analysis.fno_collective_bytes`` — the
        scattered layout moves exactly half the psum layout's interior
        bytes). Pure metadata; never traces the step."""
        from repro.roofline.analysis import fno_collective_bytes

        ctx, cfg = self.ctx, self.cfg
        tp_on = ctx is not None and ctx.model_axis is not None
        dp = 1
        if ctx is not None:
            for a in ctx.batch_axes:
                dp *= ctx.mesh.shape.get(a, 1)
        tp = ctx.mesh.shape.get(ctx.model_axis, 1) if tp_on else 1
        layout = cfg.tp_layout if tp_on else None
        scattered = layout == "scatter"
        wire = fno_collective_bytes(cfg, dp, tp, scattered=scattered,
                                    batch=self.buckets[0])
        interior = ("none" if not tp_on else
                    ("ppermute-ring" if scattered and cfg.tp_overlap
                     else "psum_scatter" if scattered else "psum"))
        return {
            "tp_layout": layout, "tp_overlap": tp_on and cfg.tp_overlap,
            "dp": dp, "tp": tp,
            "interior_collective": interior,
            "final_collective": "psum" if tp_on else "none",
            "wire_bytes_per_fwd": wire["total"],
            "wire_bytes_interior_layer": wire["interior_per_layer"],
        }

    def step_with(self, params, x: jax.Array) -> jax.Array:
        """One bucketed step with EXPLICIT params (instead of
        ``self.params``): the canary-validation hook — the resilient
        runtime (``train/serve_runtime.py``) probes candidate reload
        params through the same jit cache before swapping them in."""
        b = pick_bucket(x.shape[0], self.buckets)
        xp, m = pad_to_bucket(x, b)
        return self._step(params, {"x": xp})[:m]

    def _bucketed(self, xp: jax.Array, rollout_steps: int) -> jax.Array:
        if rollout_steps == 1:
            return self._step(self.params, {"x": xp})
        return self._rollout(self.params, {"x": xp}, steps=rollout_steps)

    def __call__(self, x: jax.Array, rollout_steps: int = 1) -> jax.Array:
        """Serve one request batch x [n, C_in, *spatial] -> [n, C_out, …].

        ``rollout_steps > 1`` runs the device-resident autoregressive
        rollout (one lax.scan — the carry never leaves HBM) and returns
        the FINAL step's prediction; the jit cache keys on (bucket,
        steps). Oversize batches are chunked at the largest bucket; the
        tail chunk pads up to its own bucket; an empty batch returns an
        empty output without touching the step."""
        if rollout_steps < 1:
            raise ValueError(f"rollout_steps must be >= 1, "
                             f"got {rollout_steps}")
        n = x.shape[0]
        if n == 0:
            return jnp.zeros(
                (0, self.cfg.out_channels) + tuple(x.shape[2:]),
                jnp.dtype(self.cfg.precision.compute_dtype))
        top = self.buckets[-1]
        ys = []
        for s in range(0, n, top):
            chunk = x[s:s + top]
            b = pick_bucket(chunk.shape[0], self.buckets)
            xp, m = pad_to_bucket(chunk, b)
            y = self._bucketed(xp, rollout_steps)
            self.stats["padded"] += b - m
            ys.append(y[:m])
        self.stats["requests"] += 1
        self.stats["samples"] += n
        return jnp.concatenate(ys, 0) if len(ys) > 1 else ys[0]
