"""Train-step factories for both model families (LM zoo and FNO).

Features: per-layer remat, microbatch gradient accumulation (the cross-
replica/pod gradient all-reduce then happens ONCE per step — XLA hoists the
psum out of the accumulation scan because the contribution is a sum, which
is the compute/communication overlap lever for multi-pod DP), AdamW update.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FNOConfig, ModelConfig
from repro.core import fno as fno_mod
from repro.models import transformer as tf
from repro.optim.adamw import AdamW, global_norm


def make_loss_fn(cfg, *, remat: bool = False, fno_path: str = "xla",
                 fno_variant: str = "full") -> Callable:
    if isinstance(cfg, FNOConfig):
        def loss_fn(params, batch):
            return fno_mod.fno_loss(params, cfg, batch, path=fno_path,
                                    variant=fno_variant)
        return loss_fn

    def loss_fn(params, batch):
        return tf.lm_loss(params, cfg, batch, remat=remat)
    return loss_fn


def _split_microbatches(batch: Dict[str, jax.Array], n: int):
    def sp(x):
        return x.reshape(n, x.shape[0] // n, *x.shape[1:])
    return jax.tree_util.tree_map(sp, batch)


def make_train_step(cfg, optimizer: AdamW, *, microbatches: int = 1,
                    remat: bool = False, fno_path: str = "xla",
                    fno_variant: str = "full", grad_acc_dtype=None):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).

    fno_path="pallas" trains on the fused kernels end-to-end: the spectral
    layers carry a custom_vjp whose backward is itself a fused Pallas
    pipeline (kernels/ops.py), so no staged-XLA fallback is involved.
    fno_variant picks full (beyond-paper) or partial (paper-faithful)
    fusion for the rank ≥ 2 pallas layers (1D has a single stage, so the
    variants coincide).

    Mixed precision: an FNOConfig carries a PrecisionPolicy
    (cfg.precision). Params stay f32 masters (init_fno), the forward/
    backward run at the compute dtype inside apply_fno and the fused
    kernels, the cast-VJPs upcast the incoming grads, and the AdamW
    update therefore happens entirely in f32 — the standard
    master-weight mixed-precision loop with zero special-casing here.

    grad_acc_dtype: dtype of the gradient-accumulation buffer (default:
    the config policy's grad_acc_dtype for FNO, else f32). The 340B+
    archs use bf16 so the FSDP-sharded buffer halves — the tradeoff that
    lets them fit 16 GB/chip at 256 chips (EXPERIMENTS.md §Dry-run)."""
    loss_fn = make_loss_fn(cfg, remat=remat, fno_path=fno_path,
                           fno_variant=fno_variant)
    if grad_acc_dtype is None and isinstance(cfg, FNOConfig):
        grad_acc_dtype = jnp.dtype(cfg.precision.grad_acc_dtype)
    acc_dt = grad_acc_dtype or jnp.float32

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        else:
            mb = _split_microbatches(batch, microbatches)

            def acc_body(carry, mbatch):
                loss_acc, g_acc = carry
                loss, g = jax.value_and_grad(loss_fn)(params, mbatch)
                g_acc = jax.tree_util.tree_map(
                    lambda a, b: (a + b.astype(acc_dt)), g_acc, g)
                return (loss_acc + loss, g_acc), None

            g0 = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), g0), mb)
            loss = loss / microbatches
            grads = jax.tree_util.tree_map(lambda g: g / microbatches, grads)

        new_params, new_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": global_norm(grads),
                   "step": new_state["step"]}
        return new_params, new_state, metrics

    return train_step
