"""Serving steps: batched prefill and single-token decode.

``prefill_step`` lowers for the *inference-prefill* shape cells;
``decode_step`` (one new token against a populated KV cache of seq_len) for
the *decode* cells, per the assignment's shape semantics.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tf


def make_prefill_step(cfg: ModelConfig, max_len: Optional[int] = None):
    def prefill_step(params, batch):
        return tf.prefill(params, cfg, batch.get("tokens"),
                          batch.get("inputs_embeds"),
                          batch.get("prefix_embeds"), max_len=max_len)
    return prefill_step


def make_decode_step(cfg: ModelConfig, sample: bool = False,
                     temperature: float = 1.0):
    def decode_step(params, cache, token, key=None):
        logits, cache = tf.decode_step(params, cfg, cache, token)
        if sample:
            nxt = jax.random.categorical(key, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), logits, cache
    return decode_step


def make_encoder_step(cfg: ModelConfig):
    """Encoder-only (hubert) 'serving' = one bidirectional forward."""
    def encoder_step(params, batch):
        logits, _ = tf.forward(params, cfg, batch.get("tokens"),
                               batch.get("inputs_embeds"),
                               batch.get("prefix_embeds"))
        return logits
    return encoder_step
