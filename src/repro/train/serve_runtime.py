"""Fault-tolerant FNO serving runtime (docs/DESIGN.md §9).

``ResilientServer`` wraps the production fused-pallas ``FNOServer`` with
the resilience layer a front-end serving millions of requests needs:

  * **bounded admission** — ``submit`` sheds load with an explicit
    ``RequestRejected`` once ``queue_limit`` requests are pending; the
    queue is never unbounded and every shed is counted.
  * **per-request deadlines** — a request that cannot be answered before
    its deadline raises ``DeadlineExceeded`` instead of holding a slot.
  * **bounded retry with exponential backoff + jitter** — replica-loss
    failures are retried on the surviving replicas (``max_retries``,
    deterministic seeded jitter so chaos replays are reproducible).
  * **health-checked replica pool** — replicas are quarantined on any
    fault, health-checked with a canary forward + finite check, and
    reinstated only when the canary passes; killed replicas stay dead.
  * **graceful degradation** — the guarded step catches kernel faults and
    non-finite outputs from the fused pallas path and re-serves THAT
    request on the staged XLA oracle path (same cfg, ``path="xla"``) —
    the ladder is pallas → XLA → reject, and every degradation increments
    ``stats["degraded"]`` so silent fallback is impossible. The fallback
    is a separate jit entry: the production step's trace stays exactly
    ``num_layers`` pallas_calls (linted by
    ``analysis.jaxpr_lint.lint_resilient_serve``).
  * **hot checkpoint reload** — ``reload()`` restores params via
    ``Checkpointer`` (``latest_valid_step`` skips corrupt steps),
    validates them with a canary forward BEFORE swapping, and rolls back
    to the serving params on any failure (``stats["rollbacks"]``).

Single-host determinism note: replicas here are pool *states* sharing one
host's jit cache — a replica id is the unit of failover/quarantine
bookkeeping, exactly what the deterministic fault harness
(``distributed/faults.py``) needs. On a real deployment each replica is
its own process/accelerator and ``Replica.forward`` is an RPC; the state
machine (healthy → quarantined → reinstated | dead) is unchanged.
"""
from __future__ import annotations

import collections
import dataclasses
import random
import time
from typing import Deque, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import FNOConfig
from repro.distributed import faults as flt
from repro.distributed import sharding as shd
from repro.train import serve_fno_step as sfs


class RequestRejected(RuntimeError):
    """Admission control shed this request (queue full). Explicit by
    design: callers see the rejection instead of unbounded queueing."""


class DeadlineExceeded(RuntimeError):
    """The request's deadline expired before an answer was produced."""


class NoHealthyReplica(RuntimeError):
    """Every replica is dead or failed its canary — nothing to serve on."""


class NonFiniteOutput(RuntimeError):
    """A forward produced NaN/Inf — treated like a kernel fault by the
    degradation ladder."""


class ReplicaLost(RuntimeError):
    """The serving replica died mid-request (failover trigger)."""


@dataclasses.dataclass
class ReplicaState:
    """Pool bookkeeping for one replica: healthy | quarantined | dead."""

    id: int
    state: str = "healthy"


class ReplicaPool:
    """Round-robin pool of health-tracked replicas.

    State machine: healthy --fault--> quarantined --canary pass-->
    healthy; healthy --kill--> dead (terminal). ``pick`` rotates over the
    healthy set; when it is empty the caller runs a health sweep first
    (quarantined replicas get one canary chance) and only then gives up.
    """

    def __init__(self, n_replicas: int):
        assert n_replicas >= 1
        self.replicas = [ReplicaState(i) for i in range(n_replicas)]
        self._rr = 0

    def healthy(self) -> List[ReplicaState]:
        return [r for r in self.replicas if r.state == "healthy"]

    def quarantined(self) -> List[ReplicaState]:
        return [r for r in self.replicas if r.state == "quarantined"]

    def pick(self) -> Optional[ReplicaState]:
        live = self.healthy()
        if not live:
            return None
        r = live[self._rr % len(live)]
        self._rr += 1
        return r

    def quarantine(self, r: ReplicaState) -> None:
        if r.state == "healthy":
            r.state = "quarantined"

    def mark_dead(self, r: ReplicaState) -> None:
        r.state = "dead"

    def reinstate(self, r: ReplicaState) -> None:
        if r.state == "quarantined":
            r.state = "healthy"

    def states(self) -> Dict[str, int]:
        out = {"healthy": 0, "quarantined": 0, "dead": 0}
        for r in self.replicas:
            out[r.state] += 1
        return out


class ResilientServer:
    """The guarded, failover-capable front end over ``FNOServer``.

    ``submit``/``drain`` is the primary API (bounded queue, deterministic
    request indices for the fault harness); ``__call__`` is the
    submit-one-drain-one convenience. All returned outputs are
    host-materialized and finite-verified numpy arrays.
    """

    STAT_KEYS = ("accepted", "shed", "served", "degraded", "failovers",
                 "retries", "quarantined", "reinstated", "killed",
                 "deadline_exceeded", "reloads", "rollbacks")

    def __init__(self, cfg: FNOConfig, params, *, replicas: int = 2,
                 ctx: Optional[shd.ShardingContext] = None,
                 variant: str = "full", max_batch: int = 8,
                 queue_limit: int = 16,
                 deadline_s: Optional[float] = None,
                 max_retries: int = 2, backoff_base_s: float = 0.01,
                 backoff_jitter: float = 0.5, seed: int = 0,
                 fault_plan: Optional[flt.FaultPlan] = None,
                 checkpointer=None):
        self.cfg, self.params, self.ctx = cfg, params, ctx
        # Production step: the fused pallas path exactly as FNOServer
        # serves it. Degraded step: the staged XLA oracle path on the SAME
        # config — a separate jit entry, so the production trace never
        # contains the fallback (DESIGN.md §9 degradation ladder).
        self.primary = sfs.FNOServer(cfg, params, ctx=ctx, path="pallas",
                                     variant=variant, max_batch=max_batch)
        self.fallback = sfs.FNOServer(cfg, params, ctx=ctx, path="xla",
                                      variant=variant, max_batch=max_batch)
        self.pool = ReplicaPool(replicas)
        self.plan = fault_plan
        self.ckpt = checkpointer
        self.queue_limit = queue_limit
        self.deadline_s = deadline_s
        self.max_retries = max_retries
        self.backoff_base_s = backoff_base_s
        self.backoff_jitter = backoff_jitter
        self._rng = random.Random(seed)  # deterministic backoff jitter
        self._pending: Deque[Tuple[int, object]] = collections.deque()
        self._req_idx = 0  # accepted-request counter (fault-plan key)
        self.stats: Dict[str, int] = {k: 0 for k in self.STAT_KEYS}
        self._canary = np.zeros(
            (self.primary.buckets[0], cfg.in_channels) + tuple(cfg.spatial),
            np.float32)

    # -- admission ----------------------------------------------------------
    def submit(self, x, rollout_steps: int = 1) -> int:
        """Admit one request batch; returns its request index. Raises
        ``RequestRejected`` (and counts the shed) when the bounded queue
        is full — load is shed explicitly, never buffered unboundedly.
        ``rollout_steps > 1`` asks for the device-resident autoregressive
        rollout (``serve_fno_step.make_fno_rollout_step``) — the guarded
        path and the degradation ladder apply to the whole trajectory."""
        if len(self._pending) >= self.queue_limit:
            self.stats["shed"] += 1
            raise RequestRejected(
                f"admission queue full ({self.queue_limit} pending) — "
                f"request shed")
        idx = self._req_idx
        self._req_idx += 1
        self._pending.append((idx, x, rollout_steps))
        self.stats["accepted"] += 1
        return idx

    def drain(self) -> List[np.ndarray]:
        """Serve every pending request in admission order, then run the
        health sweep so quarantined replicas get their canary chance."""
        out = []
        try:
            while self._pending:
                idx, x, steps = self._pending[0]
                y = self._serve_one(idx, x, steps)
                self._pending.popleft()
                self.stats["served"] += 1
                out.append(y)
        finally:
            self.health_sweep()
        return out

    def __call__(self, x, rollout_steps: int = 1) -> np.ndarray:
        self.submit(x, rollout_steps)
        return self.drain()[-1]

    # -- health -------------------------------------------------------------
    def _canary_ok(self, params=None) -> bool:
        """Canary forward + finite check (the health check / reload
        validation primitive)."""
        try:
            y = self.primary.step_with(params if params is not None
                                       else self.params, self._canary)
            return bool(np.isfinite(np.asarray(y)).all())
        except Exception:  # noqa: BLE001 — any fault fails the canary
            return False

    def health_sweep(self) -> int:
        """Give every quarantined replica one canary; reinstate on pass.
        Returns the number reinstated."""
        n = 0
        for r in self.pool.quarantined():
            if self._canary_ok():
                self.pool.reinstate(r)
                self.stats["reinstated"] += 1
                n += 1
        return n

    # -- the guarded request path ------------------------------------------
    def _check_deadline(self, deadline: Optional[float]) -> None:
        if deadline is not None and time.monotonic() > deadline:
            self.stats["deadline_exceeded"] += 1
            raise DeadlineExceeded(
                f"request missed its {self.deadline_s:.3f}s deadline")

    def _backoff(self, attempt: int) -> None:
        delay = self.backoff_base_s * (2 ** (attempt - 1))
        delay *= 1.0 + self.backoff_jitter * self._rng.random()
        time.sleep(delay)

    def _pick_replica(self) -> ReplicaState:
        r = self.pool.pick()
        if r is None:
            # Last chance: quarantined replicas get their canary now.
            self.health_sweep()
            r = self.pool.pick()
        if r is None:
            raise NoHealthyReplica(
                f"no healthy replica (pool: {self.pool.states()})")
        return r

    def _serve_one(self, idx: int, x, rollout_steps: int = 1) -> np.ndarray:
        deadline = (None if self.deadline_s is None
                    else time.monotonic() + self.deadline_s)
        attempt = 0
        while True:
            self._check_deadline(deadline)
            replica = self._pick_replica()
            # Only the serve-time kinds are consumed here; "corrupt_ckpt"
            # records stay pending for the driver (they are disk faults,
            # applied via faults.corrupt_checkpoint, not request hooks).
            planned = []
            if self.plan:
                for kind in ("delay", "kill", "kernel", "nan"):
                    planned += self.plan.take("serve", idx, kind=kind,
                                              replica=replica.id)
            try:
                for f in planned:
                    if f.kind == "delay":
                        time.sleep(f.delay_s)
                self._check_deadline(deadline)
                if any(f.kind == "kill" for f in planned):
                    self.pool.mark_dead(replica)
                    self.stats["killed"] += 1
                    raise ReplicaLost(
                        f"replica {replica.id} died serving request {idx}")
                if any(f.kind == "kernel" for f in planned):
                    raise flt.KernelFault(
                        f"injected kernel fault on replica {replica.id}, "
                        f"request {idx}")
                # Host-materialize inside the guard: deferred kernel
                # errors surface here, and the finite check needs the
                # bytes anyway.
                y = np.asarray(self.primary(x, rollout_steps))
                if any(f.kind == "nan" for f in planned):
                    y = flt.poison_output(y)
                if not np.isfinite(y).all():
                    raise NonFiniteOutput(
                        f"non-finite output from replica {replica.id} on "
                        f"request {idx}")
                return y
            except DeadlineExceeded:
                raise
            except ReplicaLost:
                # Failover: bounded retry on the surviving replicas.
                self.stats["failovers"] += 1
                attempt += 1
                if attempt > self.max_retries:
                    raise
                self.stats["retries"] += 1
                self._backoff(attempt)
                continue
            except Exception as e:  # kernel fault / NaN → degrade
                self.pool.quarantine(replica)
                self.stats["quarantined"] += 1
                y = np.asarray(self.fallback(x, rollout_steps))
                if np.isfinite(y).all():
                    self.stats["degraded"] += 1
                    return y
                # Ladder exhausted: pallas → XLA → reject.
                raise NonFiniteOutput(
                    f"request {idx}: degraded XLA path also non-finite "
                    f"(primary fault: {e})") from e

    # -- hot checkpoint reload ---------------------------------------------
    def reload(self, step: Optional[int] = None) -> bool:
        """Hot-swap params from the checkpointer. The candidate is
        validated on a canary forward BEFORE any replica serves it; any
        restore failure (corrupt step, missing step, non-finite canary)
        rolls back to the currently-serving params and returns False."""
        if self.ckpt is None:
            raise RuntimeError("reload() needs a checkpointer "
                               "(ResilientServer(checkpointer=...))")
        if step is None:
            step = self.ckpt.latest_valid_step()
        if step is None:
            self.stats["rollbacks"] += 1
            return False
        try:
            new_params = self.ckpt.restore(step, self.params)
        except Exception:  # corrupt / missing step — keep serving params
            self.stats["rollbacks"] += 1
            return False
        if not self._canary_ok(new_params):
            self.stats["rollbacks"] += 1
            return False
        self.params = new_params
        self.primary.params = new_params
        self.fallback.params = new_params
        self.stats["reloads"] += 1
        return True

    # -- introspection ------------------------------------------------------
    def pool_report(self) -> Dict[str, object]:
        """Pool + degradation counters in one dict — what the serve
        driver prints next to ``collective_plan()`` and what dashboards
        scrape (schema recorded in benchmarks/README.md)."""
        return {"replicas": self.pool.states(), **self.stats}
