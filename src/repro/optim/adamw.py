"""AdamW with global-norm clipping, written from scratch.

State dtype is configurable: large archs (nemotron-340b, arctic-480b) keep
bf16 first/second moments so optimizer state fits the per-chip HBM budget at
256 chips (EXPERIMENTS.md §Dry-run records the arithmetic). Update math is
always f32.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def _tree_map(f, *trees, **kw):
    return jax.tree_util.tree_map(f, *trees, **kw)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    state_dtype: Optional[str] = None  # None = like params; else e.g. bf16

    def init(self, params) -> Dict[str, Any]:
        dt = (lambda p: p.dtype) if self.state_dtype is None else (
            lambda p: jnp.dtype(self.state_dtype))
        zeros = lambda p: jnp.zeros(p.shape, dt(p))
        return {"m": _tree_map(zeros, params),
                "v": _tree_map(zeros, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params
               ) -> Tuple[Any, Dict[str, Any]]:
        step = state["step"] + 1
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-12)) \
            if self.clip_norm else 1.0
        lr = self.lr(step)
        b1, b2 = self.b1, self.b2
        c1 = 1 - b1 ** step.astype(jnp.float32)
        c2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32) * scale
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mhat = m32 / c1
            vhat = v32 / c2
            step_ = mhat / (jnp.sqrt(vhat) + self.eps)
            newp = (p.astype(jnp.float32)
                    - lr * (step_ + self.weight_decay * p.astype(jnp.float32)))
            return newp.astype(p.dtype), m32.astype(m.dtype), v32.astype(v.dtype)

        out = _tree_map(upd, grads, state["m"], state["v"], params)
        new_p = _tree_map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        new_m = _tree_map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        new_v = _tree_map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return new_p, {"m": new_m, "v": new_v, "step": step}


def adamw(peak_lr: float = 3e-4, **kw) -> AdamW:
    from repro.optim.schedule import constant
    return AdamW(lr=constant(peak_lr), **kw)
