"""Pure-jnp oracles for every Pallas kernel, built on jnp.fft (NOT the
matmul formulation) so kernel tests exercise a genuinely independent path.

These also serve as the "PyTorch-style staged baseline" in benchmarks: each
stage materializes its output, exactly like cuFFT → copy → cuBLAS → copy →
cuFFT in the paper's baseline.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


# -- stage oracles -----------------------------------------------------------
def ref_truncated_rdft(x: jnp.ndarray, modes: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """rfft along last axis + slice (the separate 'truncation copy kernel')."""
    xf = jnp.fft.rfft(x.astype(jnp.float32), axis=-1)[..., :modes]
    return xf.real, xf.imag


def ref_padded_irdft(xr: jnp.ndarray, xi: jnp.ndarray, n: int) -> jnp.ndarray:
    """zero-pad to n//2+1 bins (the 'padding copy kernel') + irfft."""
    modes = xr.shape[-1]
    xf = (xr + 1j * xi).astype(jnp.complex64)
    pad = [(0, 0)] * (xf.ndim - 1) + [(0, n // 2 + 1 - modes)]
    return jnp.fft.irfft(jnp.pad(xf, pad), n=n, axis=-1).astype(jnp.float32)


def ref_truncated_cdft(xr, xi, modes: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = jnp.fft.fft((xr + 1j * xi).astype(jnp.complex64), axis=-1)[..., :modes]
    return xf.real, xf.imag


def ref_padded_icdft(xr, xi, n: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    modes = xr.shape[-1]
    xf = (xr + 1j * xi).astype(jnp.complex64)
    pad = [(0, 0)] * (xf.ndim - 1) + [(0, n - modes)]
    out = jnp.fft.ifft(jnp.pad(xf, pad), n=n, axis=-1)
    return out.real.astype(jnp.float32), out.imag.astype(jnp.float32)


def ref_cgemm(ar, ai, br, bi) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Complex matmul (..., M, K) x (K, N) as 4 real matmuls."""
    cr = ar @ br - ai @ bi
    ci = ar @ bi + ai @ br
    return cr, ci


# -- fused-layer oracles -----------------------------------------------------
def ref_fnond(x: jnp.ndarray, wr: jnp.ndarray, wi: jnp.ndarray,
              modes: Tuple[int, ...]) -> jnp.ndarray:
    """Staged rank-R FNO spectral layer, TurboFNO truncation convention.

    x: [B, H, s_1..s_R]; keeps the LOW corner ``[:k_1, …, :k_R]`` only
    (paper Fig. 4 — "first dimX/DimX fraction"), unlike classic FNO's ±
    corners. W: [O, H] or [O, H, k_1..k_R]. Output [B, O, s_1..s_R].

    rFFT along s_R, FFT along the rest → truncate → CGEMM over hidden →
    zero-pad → inverse transforms. Built on jnp.fft (NOT the matmul
    formulation) so it stays a genuinely independent oracle for the engine.
    """
    r = len(modes)
    spatial = x.shape[2:]
    xf = jnp.fft.rfft(x.astype(jnp.float32), axis=-1)[..., :modes[-1]]
    for j in range(r - 1):  # FFT along s_{R-1}, …, s_1 (axes in place)
        ax = -2 - j
        xf = jnp.fft.fft(xf, axis=ax)
        xf = jax.lax.slice_in_dim(xf, 0, modes[r - 2 - j],
                                  axis=xf.ndim + ax)
    w = (wr + 1j * wi).astype(jnp.complex64)
    ms = "uvw"[:r]
    eq = (f"oh{ms},bh{ms}->bo{ms}" if w.ndim > 2
          else f"oh,bh{ms}->bo{ms}")
    yf = jnp.einsum(eq, w, xf)
    pad = [(0, 0), (0, 0)]
    pad += [(0, n - k) for n, k in zip(spatial[:-1], modes[:-1])]
    pad += [(0, spatial[-1] // 2 + 1 - modes[-1])]
    yf = jnp.pad(yf, pad)
    for j in range(r - 1):  # inverse FFT along s_1, …, s_{R-1}
        yf = jnp.fft.ifft(yf, n=spatial[j], axis=2 + j)
    return jnp.fft.irfft(yf, n=spatial[-1], axis=-1).astype(jnp.float32)


def ref_fno1d(x: jnp.ndarray, wr: jnp.ndarray, wi: jnp.ndarray,
              modes: int) -> jnp.ndarray:
    """Staged FNO 1D spectral layer. x: [B, H, N]; W: [O, H] or [O, H, modes].

    rFFT → truncate → CGEMM over hidden → zero-pad → irFFT. Output [B, O, N].
    """
    return ref_fnond(x, wr, wi, (modes,))


def ref_fno2d(x: jnp.ndarray, wr: jnp.ndarray, wi: jnp.ndarray,
              modes: Tuple[int, int]) -> jnp.ndarray:
    """Staged FNO 2D spectral layer, TurboFNO truncation convention.

    x: [B, H, X, Y]; W: [O, H] or [O, H, kx, ky]. Output [B, O, X, Y].
    """
    return ref_fnond(x, wr, wi, tuple(modes))
