"""Pure-jnp oracles for every Pallas kernel, built on jnp.fft (NOT the
matmul formulation) so kernel tests exercise a genuinely independent path.

These also serve as the "PyTorch-style staged baseline" in benchmarks: each
stage materializes its output, exactly like cuFFT → copy → cuBLAS → copy →
cuFFT in the paper's baseline.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax.numpy as jnp


# -- stage oracles -----------------------------------------------------------
def ref_truncated_rdft(x: jnp.ndarray, modes: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """rfft along last axis + slice (the separate 'truncation copy kernel')."""
    xf = jnp.fft.rfft(x.astype(jnp.float32), axis=-1)[..., :modes]
    return xf.real, xf.imag


def ref_padded_irdft(xr: jnp.ndarray, xi: jnp.ndarray, n: int) -> jnp.ndarray:
    """zero-pad to n//2+1 bins (the 'padding copy kernel') + irfft."""
    modes = xr.shape[-1]
    xf = (xr + 1j * xi).astype(jnp.complex64)
    pad = [(0, 0)] * (xf.ndim - 1) + [(0, n // 2 + 1 - modes)]
    return jnp.fft.irfft(jnp.pad(xf, pad), n=n, axis=-1).astype(jnp.float32)


def ref_truncated_cdft(xr, xi, modes: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = jnp.fft.fft((xr + 1j * xi).astype(jnp.complex64), axis=-1)[..., :modes]
    return xf.real, xf.imag


def ref_padded_icdft(xr, xi, n: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    modes = xr.shape[-1]
    xf = (xr + 1j * xi).astype(jnp.complex64)
    pad = [(0, 0)] * (xf.ndim - 1) + [(0, n - modes)]
    out = jnp.fft.ifft(jnp.pad(xf, pad), n=n, axis=-1)
    return out.real.astype(jnp.float32), out.imag.astype(jnp.float32)


def ref_cgemm(ar, ai, br, bi) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Complex matmul (..., M, K) x (K, N) as 4 real matmuls."""
    cr = ar @ br - ai @ bi
    ci = ar @ bi + ai @ br
    return cr, ci


# -- fused-layer oracles -----------------------------------------------------
def ref_fno1d(x: jnp.ndarray, wr: jnp.ndarray, wi: jnp.ndarray,
              modes: int) -> jnp.ndarray:
    """Staged FNO 1D spectral layer. x: [B, H, N]; W: [O, H] or [O, H, modes].

    rFFT → truncate → CGEMM over hidden → zero-pad → irFFT. Output [B, O, N].
    """
    n = x.shape[-1]
    xf = jnp.fft.rfft(x.astype(jnp.float32), axis=-1)[..., :modes]
    w = (wr + 1j * wi).astype(jnp.complex64)
    if w.ndim == 2:  # shared across modes (paper's CGEMM)
        yf = jnp.einsum("oh,bhm->bom", w, xf)
    else:  # per-mode (classic FNO)
        yf = jnp.einsum("ohm,bhm->bom", w, xf)
    pad = [(0, 0), (0, 0), (0, n // 2 + 1 - modes)]
    return jnp.fft.irfft(jnp.pad(yf, pad), n=n, axis=-1).astype(jnp.float32)


def ref_fno2d(x: jnp.ndarray, wr: jnp.ndarray, wi: jnp.ndarray,
              modes: Tuple[int, int]) -> jnp.ndarray:
    """Staged FNO 2D spectral layer, TurboFNO truncation convention.

    x: [B, H, X, Y]; keeps the LOW corner [:kx, :ky] only (paper Fig. 4 —
    "first dimX/DimX fraction"), unlike classic FNO's ± corners.
    W: [O, H] or [O, H, kx, ky]. Output [B, O, X, Y].
    """
    kx, ky = modes
    nx, ny = x.shape[-2:]
    xf = jnp.fft.rfft(x.astype(jnp.float32), axis=-1)[..., :ky]  # along Y
    xf = jnp.fft.fft(xf, axis=-2)[..., :kx, :]  # along X
    w = (wr + 1j * wi).astype(jnp.complex64)
    if w.ndim == 2:
        yf = jnp.einsum("oh,bhxy->boxy", w, xf)
    else:
        yf = jnp.einsum("ohxy,bhxy->boxy", w, xf)
    pad = [(0, 0), (0, 0), (0, nx - kx), (0, ny // 2 + 1 - ky)]
    yf = jnp.pad(yf, pad)
    y = jnp.fft.ifft(yf, n=nx, axis=-2)
    return jnp.fft.irfft(y, n=ny, axis=-1).astype(jnp.float32)
