"""Pallas TPU kernel: blocked complex GEMM (CGEMM).

The paper builds a CUDA-core CGEMM with m_tb=32, n_tb=32, k_tb=8 and double
smem buffering (Table 1). The TPU analogue uses MXU-aligned 128-tiles; the
k-loop is the innermost grid dimension with an f32 VMEM accumulator, and
Pallas's automatic pipelining plays the role of double buffering
(docs/DESIGN.md §2). Complex product = 4 real matmuls.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compiler_params

_F32 = jnp.float32


def _cgemm_kernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref,
                  accr, acci):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        accr[...] = jnp.zeros_like(accr)
        acci[...] = jnp.zeros_like(acci)

    ar, ai = ar_ref[...], ai_ref[...]
    br, bi = br_ref[...], bi_ref[...]
    dot = functools.partial(jax.lax.dot, preferred_element_type=_F32)
    accr[...] += dot(ar, br) - dot(ai, bi)
    acci[...] += dot(ar, bi) + dot(ai, br)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        cr_ref[...] = accr[...].astype(cr_ref.dtype)
        ci_ref[...] = acci[...].astype(ci_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "bk", "interpret"))
def cgemm_call(ar: jax.Array, ai: jax.Array, br: jax.Array, bi: jax.Array,
               bm: int = 128, bn: int = 128, bk: int = 128,
               interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """(M,K)·(K,N) complex matmul. All dims must be multiples of the blocks
    (ops.py pads)."""
    m, k = ar.shape
    _, n = br.shape
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _cgemm_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=[
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_shape=[jax.ShapeDtypeStruct((m, n), ar.dtype)] * 2,
        scratch_shapes=[pltpu.VMEM((bm, bn), _F32),
                        pltpu.VMEM((bm, bn), _F32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(ar, ai, br, bi)
