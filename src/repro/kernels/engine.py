"""Rank-generic fused truncated-DFT → CGEMM → padded-iDFT Pallas engine.

This is the single home of the paper's core contribution (§4.3) mapped to
TPU, generalized over spatial rank R (1/2/3, and any R the block shapes
fit): the per-rank kernels that used to live in ``fused_fno1d.py`` and
``fused_fno2d.py`` are emitted by the factories below, so every future
optimization (bf16 accumulators, new fusion variants) lands exactly once.

Grid and accumulator layout (identical for every rank):

  * grid = (batch tiles, out-channel tiles, hidden tiles) with the HIDDEN
    axis innermost — the FFT "pencils" are selected along the GEMM k-loop
    direction exactly as in paper Fig. 6(c);
  * per program, the truncated forward DFT chain of the x-block is computed
    straight into VMEM registers and consumed as the CGEMM A-tile — the
    shared-memory forwarding of Fig. 7 with no HBM round trip;
  * the inverse DFT chain runs as the CGEMM epilogue on the VMEM
    accumulator — Fig. 8;
  * truncation/zero-padding/pruning are implicit in the DFT operand shapes.

Every contraction is arranged so no operand needs an in-kernel transpose
(the TPU replacement for warp swizzling). ``jax.lax.dot_general`` removes
the contracted axis and appends the new spectral axis last, so the forward
chain over x[bb,bh,s_1..s_R] contracts the *current* axis of s_R, then
s_{R-1}, …, then s_1, leaving the spectrum as [bb,bh,K_R,…,K_1]:

    x[bb,bh,s_1..s_R] ─(R DFT stages)→ A[bb,bh,K_R..K_1]
    A ·(bh) W[bo,bh]                 → acc[bb,K_R..K_1,bo]   (shared W)
    acc ─(R iDFT stages)→ y[bb,bo,s_1..s_R]

For per-mode weights W[bo,bh,K_1..K_R] the CGEMM batches over every
spectral axis and the accumulator is [K_R..K_1,bb,bo]. Rank 1 reproduces
the original 1D kernel exactly; rank 2 the full-fusion 2D kernel; rank 3 is
the new 3D FNO layer.

Three kernel families:

  * ``fused_fnond_call``       — full fusion (whole layer, real in/out);
    with adjoint DFT operands and (out,hidden)-swapped weights the same
    kernel is the backward input-cotangent pipeline. Optional BLOCK
    EPILOGUE (``wb``/``bias``/``act``): the 1×1 bypass conv of the
    standard FNO block ``gelu(spectral(h) + bypass(h) + bias)`` contracts
    the same hidden axis as the CGEMM k-loop, so its GEMM rides the same
    grid into a third VMEM accumulator and the last-k epilogue applies
    ``+bypass → +bias → gelu`` before the single ref write — one
    pallas_call for the whole FNO block. ``act="gelu_vjp"`` is the
    backward recompute: the epilogue forms ``gz = gy·gelu'(z)`` from the
    recomputed pre-activation without materializing z in HBM.
  * ``fused_fnond_core_call``  — paper-faithful partial fusion: only the
    DFT stage adjacent to the CGEMM is fused (complex in/out); the outer
    R-1 transforms run as standalone kernels (dft.py), matching TurboFNO,
    which fuses only the FFT stage next to the GEMM.
  * ``fused_fnond_wgrad_call`` — fused rank-reduction weight gradient:
    both the primal spectrum A and the cotangent spectrum Ĝ are computed
    in VMEM and consumed by the reduction without an HBM round trip.
    ``with_bypass=True`` additionally emits the bypass-weight cotangent
    ``dW_b = Σ gz·xᵀ`` and ``dbias = Σ gz`` from the x/gz refs the
    spectral reduction already holds in VMEM — no extra HBM pass.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compiler_params

_F32 = jnp.float32
_SEMANTICS = ("parallel", "parallel", "arbitrary")

# Version tag of the engine's launch geometry — the facts a persisted
# block-size plan depends on: the grid axes and their meaning, which
# operands are grid-blocked vs constant-index, and the accumulator/scratch
# layout per launch kind. The tuning cache (repro.tuning) stamps this into
# its meta and the contract linter refuses a cache tuned against another
# signature. BUMP THE VERSION whenever a change to the kernels below moves
# bytes in or out of a program's VMEM window (new operands, scratch shape
# changes, grid reorderings) — stale winners would otherwise keep passing.
# The fused-ends operands (lift/proj, PR 8) do NOT bump it: they are new
# OPTIONAL operands absent from every launch kind the cache tunes — an
# ends-fused launch reuses the block_fwd plan with bo pinned to the padded
# O, so tuned winners for the default launches stay exactly valid.
BLOCK_SIGNATURE = ("fnond-v1:grid=(b/bb,o/bo,h/bh);wgrad-grid=(o/bo,h/bh,"
                   "b/bb);acc=rev_modes@accum+bypass;launches=block_fwd,"
                   "gz_recompute,dx_adjoint,wgrad,core")


def _dot(a, b, axis, acc=_F32):
    """Contract `axis` of a with dim 0 of b; the new dim is appended last.

    `acc` is the MXU accumulation dtype (PrecisionPolicy.accum_dtype —
    stays f32 under the bf16 policy so only the ref-write boundaries cast
    down)."""
    return jax.lax.dot_general(a, b, (((axis,), (0,)), ((), ())),
                               preferred_element_type=acc)


def _cstage(zr, zi, mr, mi, axis, acc=_F32):
    """One complex DFT stage: (zr + i·zi) · (mr + i·mi) along `axis`.

    zi=None marks a real input (the first rDFT stage) — the imaginary
    products vanish.
    """
    if zi is None:
        return _dot(zr, mr, axis, acc), _dot(zr, mi, axis, acc)
    return (_dot(zr, mr, axis, acc) - _dot(zi, mi, axis, acc),
            _dot(zr, mi, axis, acc) + _dot(zi, mr, axis, acc))


def _dft_chain(z, mats, rank, acc=_F32):
    """Run the forward DFT chain over the trailing `rank` spatial axes.

    z: [bb,bc,s_1..s_R] real; mats: flat (mr, mi) pairs in stage order
    (axis s_R first). Returns the spectrum pair [bb,bc,K_R..K_1].
    """
    zr, zi = z, None
    for i in range(rank):
        zr, zi = _cstage(zr, zi, mats[2 * i][...], mats[2 * i + 1][...],
                         1 + rank - i, acc)
    return zr, zi


_GELU_C = 0.7978845608028654  # sqrt(2/pi)
_GELU_A = 0.044715


def _dgelu(z):
    """d/dz of the tanh-approximate GELU (jax.nn.gelu approximate=True,
    the activation core/fno.py applies): with u = c·(z + a·z³),
    gelu'(z) = ½(1+tanh u) + ½·z·(1−tanh²u)·c·(1+3a·z²)."""
    z2 = z * z
    t = jnp.tanh(_GELU_C * z * (1.0 + _GELU_A * z2))
    return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t * t) * _GELU_C * (
        1.0 + 3.0 * _GELU_A * z2)


# ---------------------------------------------------------------------------
# Full fusion: [rDFT → cDFT… → CGEMM → icDFT… → irDFT] in one kernel.
# With the block epilogue (has_wb): the bypass GEMM x·W_bᵀ accumulates in a
# third VMEM scratch during the same hidden k-loop, and the last-k epilogue
# computes gelu(iDFT(acc) + bypass + bias) before the single ref write.
#
# Fused MODEL ENDS (has_lift / has_proj — the lifting and projection MLPs
# folded into the first/last block kernel, DESIGN.md §6):
#   * has_lift: the x ref is the RAW model input [bb, C_in, s…] (constant
#     over the k grid). At k==0 the lift prologue computes the inner
#     activation a = gelu(W_l1ᵀ·x + b_l1) once into a scratch that persists
#     across the hidden loop; every k step then forms its hidden block
#     h_k = W_l2ᵀ[k]·a + b_l2[k] in VMEM and feeds it to the DFT chain and
#     bypass MAC — the lifted activations never round-trip HBM.
#   * has_proj: requires a single out-channel grid step (bo = padded O,
#     the projection contracts the FULL hidden width). The epilogue pushes
#     the activated block output straight through the projection MLP —
#     y = W_p2ᵀ·gelu(W_p1ᵀ·z + b_p1) + b_p2 — and the ref write emits the
#     model OUTPUT channels [bb, C_out, s…].
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _make_fwd_kernel(rank: int, per_mode: bool, acc_dtype: str = "float32",
                     has_wb: bool = False, has_bias: bool = False,
                     act: str = "linear", has_lift: bool = False,
                     has_proj: bool = False):
    r = rank
    acc = jnp.dtype(acc_dtype)
    has_gy = act == "gelu_vjp"

    def kernel(*refs):
        x_ref, wr_ref, wi_ref = refs[:3]
        pos = 3
        fwd = refs[pos:pos + 2 * r]
        inv = refs[pos + 2 * r:pos + 4 * r]
        pos += 4 * r
        wb_ref = bias_ref = gy_ref = accb = acca = None
        lift_refs = proj_refs = None
        if has_wb:
            wb_ref = refs[pos]
            pos += 1
        if has_bias:
            bias_ref = refs[pos]
            pos += 1
        if has_gy:
            gy_ref = refs[pos]
            pos += 1
        if has_lift:
            lift_refs = refs[pos:pos + 4]  # l1w [L,Ci], l1b, l2w, l2b
            pos += 4
        if has_proj:
            proj_refs = refs[pos:pos + 4]  # p1w [L,O], p1b, p2w, p2b
            pos += 4
        y_ref = refs[pos]
        accr, acci = refs[pos + 1:pos + 3]
        pos += 3
        if has_wb:
            accb = refs[pos]
            pos += 1
        if has_lift:
            acca = refs[pos]

        def _colvec(ref, nd):
            # [D,1] operand broadcast over the trailing batch/spatial dims.
            return ref[...].reshape((-1,) + (1,) * nd)

        @pl.when(pl.program_id(2) == 0)
        def _init():
            accr[...] = jnp.zeros_like(accr)
            acci[...] = jnp.zeros_like(acci)
            if has_wb:
                accb[...] = jnp.zeros_like(accb)
            if has_lift:
                # Lift prologue, once per (i,j): a = gelu(W_l1ᵀ·x + b_l1)
                # → [L, bb, s…], persisted across the hidden k-loop.
                a = jax.lax.dot_general(
                    lift_refs[0][...], x_ref[...],
                    (((1,), (1,)), ((), ())), preferred_element_type=acc)
                a = a + _colvec(lift_refs[1], 1 + r)
                acca[...] = jax.nn.gelu(a, approximate=True)

        if has_lift:
            # This k step's hidden block: h_k = W_l2ᵀ[k]·a + b_l2[k],
            # realigned [L,bb,…]→[bb,bh,…] by a major-axes swap.
            hk = jax.lax.dot_general(
                lift_refs[2][...], acca[...], (((1,), (0,)), ((), ())),
                preferred_element_type=acc)
            hk = hk + _colvec(lift_refs[3], 1 + r)
            xblk = jnp.swapaxes(hk, 0, 1).astype(x_ref.dtype)
        else:
            xblk = x_ref[...]

        # Truncated forward DFT chain — the FFT writing its A-tile to
        # "shared memory" (VMEM registers).
        ar, ai = _dft_chain(xblk, fwd, r, acc)

        # CGEMM over hidden (the k-loop MAC).
        wr, wi = wr_ref[...], wi_ref[...]
        if per_mode:
            # Batch every spectral axis: A's are reversed (K_R..K_1)
            # relative to W[bo,bh,K_1..K_R].
            dims = (((1,), (1,)),
                    (tuple(range(2, 2 + r)), tuple(range(1 + r, 1, -1))))
        else:
            dims = (((1,), (1,)), ((), ()))

        def dg(a, w):
            return jax.lax.dot_general(a, w, dims,
                                       preferred_element_type=acc)

        accr[...] += dg(ar, wr) - dg(ai, wi)
        acci[...] += dg(ar, wi) + dg(ai, wr)

        if has_wb:
            # Bypass GEMM riding the same k-loop MAC: W_b[bo,bh]·x[bb,bh,s…]
            # → [bo,bb,s…]. The bo-leading layout keeps the minor (spatial)
            # dims in place so the epilogue's realign is a major-axes swap.
            accb[...] += jax.lax.dot_general(
                wb_ref[...], xblk, (((1,), (1,)), ((), ())),
                preferred_element_type=acc)

        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _epilogue():
            # Padded inverse DFT chain; only the real part of the final
            # stage is materialized (real output).
            tr, ti = accr[...], acci[...]
            z = None
            for i in range(r):
                axis = (r - 1 - i) if per_mode else (r - i)
                mr, mi = inv[2 * i][...], inv[2 * i + 1][...]
                if i < r - 1:
                    tr, ti = _cstage(tr, ti, mr, mi, axis, acc)
                else:
                    z = (_dot(tr, mr, axis, acc)
                         - _dot(ti, mi, axis, acc))
            # Block epilogue: + bypass + bias → activation, all on the
            # f32 VMEM values — HBM sees only the final activation.
            if has_wb:
                z = z + jnp.swapaxes(accb[...], 0, 1)
            if has_bias:
                z = z + bias_ref[...].reshape((1, -1) + (1,) * r)
            if act == "gelu":
                z = jax.nn.gelu(z, approximate=True)
            elif act == "gelu_vjp":
                z = gy_ref[...].astype(acc) * _dgelu(z)
            if has_proj:
                # Projection epilogue on the activated block output z
                # [bb,O,s…] (bo == padded O — single j step): the pointwise
                # MLP contracts the full hidden width in VMEM and the ref
                # write emits the model's output channels.
                a2 = jax.lax.dot_general(
                    proj_refs[0][...], z.astype(acc),
                    (((1,), (1,)), ((), ())), preferred_element_type=acc)
                a2 = jax.nn.gelu(a2 + _colvec(proj_refs[1], 1 + r),
                                 approximate=True)
                out = jax.lax.dot_general(
                    proj_refs[2][...], a2, (((1,), (0,)), ((), ())),
                    preferred_element_type=acc)
                z = jnp.swapaxes(out + _colvec(proj_refs[3], 1 + r), 0, 1)
            y_ref[...] = z.astype(y_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("bb", "bo", "bh", "interpret",
                                             "out_dtype", "acc_dtype",
                                             "act"))
def fused_fnond_call(x: jax.Array, wr: jax.Array, wi: jax.Array,
                     *mats: jax.Array, bb: int, bo: int, bh: int,
                     interpret: bool = False, out_dtype: str = None,
                     acc_dtype: str = "float32", wb: jax.Array = None,
                     bias: jax.Array = None, gy: jax.Array = None,
                     act: str = "linear", lift: Tuple = None,
                     proj: Tuple = None) -> jax.Array:
    """Whole rank-R FNO spectral layer — or FNO block — in one kernel.

    x: [B,H,s_1..s_R] real; w: [O,H] or [O,H,K_1..K_R]; mats: flat
    (mr, mi) operand pairs — R forward stages ([n,k], axis s_R first) then
    R inverse stages ([k,n], axis s_1 first), as produced by
    ``spectral.fused_operand_mats``. Returns y [B,O,s_1..s_R] real.

    All of B,O,H must divide by (bb,bo,bh); spatial/modes dims are whole
    blocks (ops.py pads). out_dtype overrides the output dtype (default:
    x.dtype — the backward pass emits dx at the primal dtype straight from
    the f32 accumulator); acc_dtype is the VMEM accumulator dtype
    (PrecisionPolicy.accum_dtype).

    Block epilogue (all optional, see ``fused_fno_block_call``):
    wb [O,H] accumulates the 1×1 bypass GEMM alongside the CGEMM k-loop;
    bias [O,1] adds per-out-channel; act picks the epilogue nonlinearity —
    "linear" (default), "gelu" (forward block), or "gelu_vjp" (backward
    recompute: requires gy [B,O,s_1..s_R] and emits gy·gelu'(z)).

    Fused model ends (forward block kernels only — incompatible with gy):
    lift = (l1w [L,C_in], l1b [L,1], l2w [H,L], l2b [H,1]) folds the
    lifting MLP into the kernel — x is then the RAW input [B,C_in,s…] and
    each k step derives its hidden block in VMEM (prologue at k==0 caches
    the inner activation). proj = (p1w [L,O], p1b [L,1], p2w [C_out,L],
    p2b [C_out,1]) folds the projection MLP into the epilogue — requires
    bo == O (single out-channel grid step) and the result is
    [B,C_out,s…]. These launches reuse the block_fwd tuned plan with bo
    pinned; the default launches are unchanged (BLOCK_SIGNATURE stable).
    """
    r = x.ndim - 2
    b = x.shape[0]
    h = lift[2].shape[0] if lift is not None else x.shape[1]
    spatial = x.shape[2:]
    o = wr.shape[0]
    per_mode = wr.ndim == 2 + r
    assert len(mats) == 4 * r, (len(mats), r)
    assert act in ("linear", "gelu", "gelu_vjp"), act
    assert (gy is not None) == (act == "gelu_vjp"), act
    assert gy is None or (lift is None and proj is None), \
        "fused ends are forward-only (backward is the staged vjp)"
    assert proj is None or bo == o, \
        "the projection epilogue contracts the full padded O: bo must == O"
    # Spectral extents in accumulator order (K_R .. K_1).
    rev_modes = tuple(m.shape[1] for m in mats[:2 * r:2])
    grid = (b // bb, o // bo, h // bh)
    zr = (0,) * r

    if lift is not None:
        # Raw-input block: full (small) channel dim, constant over k.
        x_spec = pl.BlockSpec((bb, x.shape[1]) + spatial,
                              lambda i, j, k: (i, 0) + zr)
    else:
        x_spec = pl.BlockSpec((bb, bh) + spatial,
                              lambda i, j, k: (i, k) + zr)
    if per_mode:
        w_spec = pl.BlockSpec((bo, bh) + wr.shape[2:],
                              lambda i, j, k: (j, k) + zr)
        acc_shape = rev_modes + (bb, bo)
    else:
        w_spec = pl.BlockSpec((bo, bh), lambda i, j, k: (j, k))
        acc_shape = (bb,) + rev_modes + (bo,)
    m_specs = [pl.BlockSpec(m.shape, lambda i, j, k: (0, 0)) for m in mats]
    out_ch = proj[2].shape[0] if proj is not None else o
    if proj is not None:
        y_spec = pl.BlockSpec((bb, out_ch) + spatial,
                              lambda i, j, k: (i, 0) + zr)
    else:
        y_spec = pl.BlockSpec((bb, bo) + spatial,
                              lambda i, j, k: (i, j) + zr)

    operands = [x, wr, wi, *mats]
    in_specs = [x_spec, w_spec, w_spec] + m_specs
    acc = jnp.dtype(acc_dtype)
    scratch = [pltpu.VMEM(acc_shape, acc), pltpu.VMEM(acc_shape, acc)]
    if wb is not None:
        operands.append(wb)
        in_specs.append(pl.BlockSpec((bo, bh), lambda i, j, k: (j, k)))
        scratch.append(pltpu.VMEM((bo, bb) + spatial, acc))
    if bias is not None:
        operands.append(bias)
        in_specs.append(pl.BlockSpec((bo, 1), lambda i, j, k: (j, 0)))
    if gy is not None:
        operands.append(gy)
        in_specs.append(y_spec)
    if lift is not None:
        l1w, l1b, l2w, l2b = lift
        operands += [l1w, l1b, l2w, l2b]
        in_specs += [
            pl.BlockSpec(l1w.shape, lambda i, j, k: (0, 0)),
            pl.BlockSpec(l1b.shape, lambda i, j, k: (0, 0)),
            pl.BlockSpec((bh, l2w.shape[1]), lambda i, j, k: (k, 0)),
            pl.BlockSpec((bh, 1), lambda i, j, k: (k, 0)),
        ]
    if proj is not None:
        operands += list(proj)
        in_specs += [pl.BlockSpec(p.shape, lambda i, j, k: (0, 0))
                     for p in proj]
    if lift is not None:
        # The persisted lift activation a [L, bb, s…] (k-invariant).
        scratch.append(pltpu.VMEM((lift[0].shape[0], bb) + spatial, acc))

    return pl.pallas_call(
        _make_fwd_kernel(r, per_mode, acc_dtype, wb is not None,
                         bias is not None, act, lift is not None,
                         proj is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((b, out_ch) + spatial,
                                       jnp.dtype(out_dtype or x.dtype)),
        scratch_shapes=scratch,
        compiler_params=_compiler_params(dimension_semantics=_SEMANTICS),
        interpret=interpret,
    )(*operands)


def fused_fno_block_call(x: jax.Array, wr: jax.Array, wi: jax.Array,
                         wb: jax.Array, bias: jax.Array, *mats: jax.Array,
                         bb: int, bo: int, bh: int, interpret: bool = False,
                         out_dtype: str = None,
                         acc_dtype: str = "float32") -> jax.Array:
    """One whole FNO block — gelu(spectral(x) + x·W_bᵀ + bias) — in a
    single pallas_call (the paper's fusion thesis extended to the full
    block). wb: [O,H] bypass 1×1 weight; bias: [O,1]; everything else as
    ``fused_fnond_call``."""
    return fused_fnond_call(x, wr, wi, *mats, bb=bb, bo=bo, bh=bh,
                            interpret=interpret, out_dtype=out_dtype,
                            acc_dtype=acc_dtype, wb=wb, bias=bias,
                            act="gelu")


# ---------------------------------------------------------------------------
# Paper-faithful partial fusion: [cDFT_s1 → CGEMM → icDFT_s1] on complex
# input whose outer axes were already transformed by standalone kernels.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _make_core_kernel(n_spec: int, per_mode: bool,
                      acc_dtype: str = "float32"):
    s = n_spec  # trailing already-spectral axes (K_R .. K_2)
    acc = jnp.dtype(acc_dtype)

    def kernel(zr_ref, zi_ref, wr_ref, wi_ref, fr_ref, fi_ref,
               gr_ref, gi_ref, yr_ref, yi_ref, accr, acci):
        @pl.when(pl.program_id(2) == 0)
        def _init():
            accr[...] = jnp.zeros_like(accr)
            acci[...] = jnp.zeros_like(acci)

        # Truncated cDFT along s_1 (the GEMM-adjacent stage): contract
        # dim 2 -> [bb,bh,K_R..K_2,K_1].
        ar, ai = _cstage(zr_ref[...], zi_ref[...], fr_ref[...], fi_ref[...],
                         2, acc)
        wr, wi = wr_ref[...], wi_ref[...]
        if per_mode:
            dims = (((1,), (1,)),
                    (tuple(range(2, 3 + s)), tuple(range(2 + s, 1, -1))))
        else:
            dims = (((1,), (1,)), ((), ()))

        def dg(a, w):
            return jax.lax.dot_general(a, w, dims,
                                       preferred_element_type=acc)

        accr[...] += dg(ar, wr) - dg(ai, wi)
        acci[...] += dg(ar, wi) + dg(ai, wr)

        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _epilogue():
            # Padded icDFT along s_1 (complex output pair).
            axis = s if per_mode else 1 + s
            tr, ti = _cstage(accr[...], acci[...], gr_ref[...], gi_ref[...],
                             axis, acc)
            yr_ref[...] = tr.astype(yr_ref.dtype)
            yi_ref[...] = ti.astype(yi_ref.dtype)

    return kernel


@functools.partial(jax.jit, static_argnames=("bb", "bo", "bh", "interpret",
                                             "acc_dtype"))
def fused_fnond_core_call(zr: jax.Array, zi: jax.Array, wr: jax.Array,
                          wi: jax.Array, fr: jax.Array, fi: jax.Array,
                          gr: jax.Array, gi: jax.Array, *, bb: int, bo: int,
                          bh: int, interpret: bool = False,
                          acc_dtype: str = "float32"
                          ) -> Tuple[jax.Array, jax.Array]:
    """Partial-fusion middle: z [B,H,s_1,K_R..K_2] complex pair (outer
    stages already applied); w [O,H] or [O,H,K_1..K_R]; f [s_1,K_1];
    g [K_1,s_1]. Returns the y pair — [B,K_R..K_2,O,s_1] shared, or
    [K_R..K_2,B,O,s_1] per-mode (caller transposes)."""
    b, h, nx = zr.shape[:3]
    spec = zr.shape[3:]
    s = len(spec)
    o = wr.shape[0]
    per_mode = wr.ndim > 2
    kx = fr.shape[1]
    grid = (b // bb, o // bo, h // bh)
    zs = (0,) * s

    z_spec = pl.BlockSpec((bb, bh, nx) + spec,
                          lambda i, j, k: (i, k, 0) + zs)
    if per_mode:
        w_spec = pl.BlockSpec((bo, bh) + wr.shape[2:],
                              lambda i, j, k: (j, k) + (0,) * (wr.ndim - 2))
        y_shape = spec + (b, o, nx)
        y_spec = pl.BlockSpec(spec + (bb, bo, nx),
                              lambda i, j, k: zs + (i, j, 0))
        acc_shape = spec + (kx, bb, bo)
    else:
        w_spec = pl.BlockSpec((bo, bh), lambda i, j, k: (j, k))
        y_shape = (b,) + spec + (o, nx)
        y_spec = pl.BlockSpec((bb,) + spec + (bo, nx),
                              lambda i, j, k: (i,) + zs + (j, 0))
        acc_shape = (bb,) + spec + (kx, bo)
    mat = lambda m: pl.BlockSpec(m.shape, lambda i, j, k: (0, 0))
    out_sd = jax.ShapeDtypeStruct(y_shape, zr.dtype)

    acc = jnp.dtype(acc_dtype)
    return pl.pallas_call(
        _make_core_kernel(s, per_mode, acc_dtype),
        grid=grid,
        in_specs=[z_spec, z_spec, w_spec, w_spec, mat(fr), mat(fi),
                  mat(gr), mat(gi)],
        out_specs=[y_spec, y_spec],
        out_shape=[out_sd, out_sd],
        scratch_shapes=[pltpu.VMEM(acc_shape, acc),
                        pltpu.VMEM(acc_shape, acc)],
        compiler_params=_compiler_params(dimension_semantics=_SEMANTICS),
        interpret=interpret,
    )(zr, zi, wr, wi, fr, fi, gr, gi)


# ---------------------------------------------------------------------------
# Fused weight gradient (backward pass of the spectral layer).
#
# With A = the truncated rank-R spectrum of x ([B,H,K_R..K_1]) and
# Ĝ = the output cotangent pushed into the spectral domain through the
# transposed inverse transforms ([B,O,K_R..K_1]), the weight cotangent is
#
#     dW[o,h(,modes)] = conj( Σ_b Ĝ[b,o,…]·A[b,h,…] )   (Σ_modes too when
#                                                        shared)
#
# — a fused rank reduction: both spectra are computed straight into VMEM
# and consumed without an HBM round trip, mirroring the forward kernel's
# Fig. 7 forwarding. Grid = (out, hidden, batch) with BATCH innermost as
# the accumulation loop.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=None)
def _make_wgrad_kernel(rank: int, per_mode: bool,
                       acc_dtype: str = "float32",
                       with_bypass: bool = False):
    r = rank
    acc = jnp.dtype(acc_dtype)

    def kernel(*refs):
        x_ref, g_ref = refs[:2]
        xm = refs[2:2 + 2 * r]          # forward-spectrum operands (A)
        gm = refs[2 + 2 * r:2 + 4 * r]  # adjoint forward operands (Ĝ)
        pos = 2 + 4 * r
        dwr_ref, dwi_ref = refs[pos:pos + 2]
        pos += 2
        dwb_ref = db_ref = accwb = accdb = None
        if with_bypass:
            dwb_ref, db_ref = refs[pos:pos + 2]
            pos += 2
        accr, acci = refs[pos:pos + 2]
        if with_bypass:
            accwb, accdb = refs[pos + 2:pos + 4]

        @pl.when(pl.program_id(2) == 0)
        def _init():
            accr[...] = jnp.zeros_like(accr)
            acci[...] = jnp.zeros_like(acci)
            if with_bypass:
                accwb[...] = jnp.zeros_like(accwb)
                accdb[...] = jnp.zeros_like(accdb)

        ar, ai = _dft_chain(x_ref[...], xm, r, acc)  # A: [bb,bh,K_R..K_1]
        hr, hi = _dft_chain(g_ref[...], gm, r, acc)  # Ĝ: [bb,bo,K_R..K_1]

        if per_mode:  # batch the spectral axes, contract batch
            dims = (((0,), (0,)),
                    (tuple(range(2, 2 + r)), tuple(range(2, 2 + r))))
        else:  # contract batch AND every spectral axis -> [bo,bh]
            both = (0,) + tuple(range(2, 2 + r))
            dims = ((both, both), ((), ()))

        def rdot(p, q):
            return jax.lax.dot_general(p, q, dims,
                                       preferred_element_type=acc)

        accr[...] += rdot(hr, ar) - rdot(hi, ai)
        acci[...] += rdot(hr, ai) + rdot(hi, ar)

        if with_bypass:
            # Bypass cotangents from the refs already resident in VMEM:
            # dW_b = Σ_{b,s} gz·x (contract batch + every spatial axis)
            # and dbias = Σ_{b,s} gz — no extra HBM pass.
            sp_axes = (0,) + tuple(range(2, 2 + r))
            accwb[...] += jax.lax.dot_general(
                g_ref[...], x_ref[...], ((sp_axes, sp_axes), ((), ())),
                preferred_element_type=acc)
            accdb[...] += jnp.sum(g_ref[...].astype(acc),
                                  axis=sp_axes)[:, None]

        @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
        def _epilogue():
            # dW = conj(acc): real part as-is, imaginary part negated.
            dwr_ref[...] = accr[...].astype(dwr_ref.dtype)
            dwi_ref[...] = (-acci[...]).astype(dwi_ref.dtype)
            if with_bypass:  # real operands — no conjugation
                dwb_ref[...] = accwb[...].astype(dwb_ref.dtype)
                db_ref[...] = accdb[...].astype(db_ref.dtype)

    return kernel


@functools.partial(
    jax.jit, static_argnames=("bb", "bo", "bh", "per_mode", "interpret",
                              "out_dtype", "acc_dtype", "with_bypass"))
def fused_fnond_wgrad_call(x: jax.Array, g: jax.Array, *mats: jax.Array,
                           bb: int, bo: int, bh: int, per_mode: bool,
                           interpret: bool = False, out_dtype: str = None,
                           acc_dtype: str = "float32",
                           with_bypass: bool = False
                           ) -> Tuple[jax.Array, ...]:
    """x: [B,H,s_1..s_R] primal; g: [B,O,s_1..s_R] cotangent; mats: flat
    (mr, mi) pairs — R forward stages for x then R adjoint-forward stages
    for g (each [n,k], axis s_R first), as produced by
    ``spectral.wgrad_operand_mats``.

    Returns (dwr, dwi): [O,H] shared, or [K_R..K_1,O,H] per-mode (caller
    transposes back to [O,H,K_1..K_R]). out_dtype sets the dW emission
    dtype (PrecisionPolicy.param_dtype under mixed precision: cotangents
    accumulate at acc_dtype in VMEM, dW is cast once at the ref write).

    with_bypass=True (the fused-block backward) appends the bypass-GEMM
    cotangents to the return — (dwr, dwi, dwb [O,H], dbias [O,1]) — formed
    from the x/g refs the spectral reduction already holds in VMEM.
    """
    r = x.ndim - 2
    b, h = x.shape[:2]
    spatial = x.shape[2:]
    o = g.shape[1]
    assert len(mats) == 4 * r, (len(mats), r)
    rev_modes = tuple(m.shape[1] for m in mats[:2 * r:2])
    grid = (o // bo, h // bh, b // bb)
    zr = (0,) * r

    x_spec = pl.BlockSpec((bb, bh) + spatial, lambda i, j, kb: (kb, j) + zr)
    g_spec = pl.BlockSpec((bb, bo) + spatial, lambda i, j, kb: (kb, i) + zr)
    m_specs = [pl.BlockSpec(m.shape, lambda i, j, kb: (0, 0)) for m in mats]
    if per_mode:
        dw_spec = pl.BlockSpec(rev_modes + (bo, bh),
                               lambda i, j, kb: zr + (i, j))
        dw_shape = rev_modes + (o, h)
        acc_shape = rev_modes + (bo, bh)
    else:
        dw_spec = pl.BlockSpec((bo, bh), lambda i, j, kb: (i, j))
        dw_shape = (o, h)
        acc_shape = (bo, bh)
    od = jnp.dtype(out_dtype or x.dtype)
    out_sd = jax.ShapeDtypeStruct(dw_shape, od)

    acc = jnp.dtype(acc_dtype)
    out_specs = [dw_spec, dw_spec]
    out_shape = [out_sd, out_sd]
    scratch = [pltpu.VMEM(acc_shape, acc), pltpu.VMEM(acc_shape, acc)]
    if with_bypass:
        # dwb [O,H] per (i,j) block; dbias [O,1] is j-independent — every
        # j program re-derives and writes the identical block (idempotent).
        out_specs += [pl.BlockSpec((bo, bh), lambda i, j, kb: (i, j)),
                      pl.BlockSpec((bo, 1), lambda i, j, kb: (i, 0))]
        out_shape += [jax.ShapeDtypeStruct((o, h), od),
                      jax.ShapeDtypeStruct((o, 1), od)]
        scratch += [pltpu.VMEM((bo, bh), acc), pltpu.VMEM((bo, 1), acc)]
    return pl.pallas_call(
        _make_wgrad_kernel(r, per_mode, acc_dtype, with_bypass),
        grid=grid,
        in_specs=[x_spec, g_spec] + m_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        compiler_params=_compiler_params(dimension_semantics=_SEMANTICS),
        interpret=interpret,
    )(x, g, *mats)
