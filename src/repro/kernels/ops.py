"""Public jit'd wrappers around the Pallas kernels.

Handles (8,128)-alignment padding, block-size selection, and path dispatch:

  path="ref"    — jnp.fft staged oracle (the "PyTorch baseline")
  path="xla"    — truncated-DFT matmul formulation, fused by XLA (runs on
                  any backend; this is what the distributed dry-run lowers)
  path="pallas" — the fused TurboFNO kernels (interpret=True off-TPU)

Padding rules: modes K and channel dims are padded with zeros — padded DFT
rows/weight entries contribute exactly zero through the linear pipeline, so
results are sliced back without error.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import spectral
from repro.kernels import cgemm as cgemm_k
from repro.kernels import dft as dft_k
from repro.kernels import fused_fno1d as f1d
from repro.kernels import fused_fno2d as f2d
from repro.kernels import ref as ref_k


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag: Optional[bool]) -> bool:
    return (not on_tpu()) if flag is None else flag


def _rup(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def _pick_block(dim: int, pref: int) -> int:
    """Largest divisor-friendly block: pad dim up to a multiple of block."""
    return min(pref, _rup(dim, 8)) if dim < pref else pref


def _blocks(x, o, bb, bo, bh):
    """Resolve (bb,bo,bh) block sizes and padded (B,O,H) for x[B,H,...]."""
    b, h = x.shape[:2]
    bb = _pick_block(b, bb)
    bo = _pick_block(o, bo)
    bh = _pick_block(h, bh)
    return bb, bo, bh, _rup(b, bb), _rup(o, bo), _rup(h, bh)


# ---------------------------------------------------------------------------
# Standalone truncated-DFT kernels (paper §3.3 — FFT w/ built-in filtering)
# ---------------------------------------------------------------------------
def truncated_rdft(x: jax.Array, modes: int, *, path: str = "pallas",
                   block_rows: int = 256,
                   interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """rFFT along the last axis keeping `modes` bins. x: [..., N]."""
    if path == "ref":
        return ref_k.ref_truncated_rdft(x, modes)
    if path == "xla":
        return spectral.truncated_rdft(x, modes)
    n = x.shape[-1]
    lead = x.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    kp = _rup(modes, 128)
    cr, ci = spectral.rdft_mats(n, modes)
    cr = _pad_axis(jnp.asarray(cr, x.dtype), 1, kp)
    ci = _pad_axis(jnp.asarray(ci, x.dtype), 1, kp)
    br = _pick_block(m, block_rows)
    x2 = _pad_axis(x.reshape(m, n), 0, _rup(m, br))
    xr, xi = dft_k._rdft_call(x2, cr, ci, br, _interpret(interpret))
    return (xr[:m, :modes].reshape(*lead, modes),
            xi[:m, :modes].reshape(*lead, modes))


def padded_irdft(xr: jax.Array, xi: jax.Array, n: int, *,
                 path: str = "pallas", block_rows: int = 256,
                 interpret: Optional[bool] = None) -> jax.Array:
    """Inverse rFFT from `modes` bins zero-padded to length n."""
    if path == "ref":
        return ref_k.ref_padded_irdft(xr, xi, n)
    if path == "xla":
        return spectral.padded_irdft(xr, xi, n)
    modes = xr.shape[-1]
    lead = xr.shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    er, ei = spectral.irdft_mats(n, modes)
    kp = _rup(modes, 128)
    er = _pad_axis(jnp.asarray(er, xr.dtype), 0, kp)
    ei = _pad_axis(jnp.asarray(ei, xr.dtype), 0, kp)
    br = _pick_block(m, block_rows)
    mp = _rup(m, br)
    xr2 = _pad_axis(_pad_axis(xr.reshape(m, modes), 1, kp), 0, mp)
    xi2 = _pad_axis(_pad_axis(xi.reshape(m, modes), 1, kp), 0, mp)
    y = dft_k._irdft_call(xr2, xi2, er, ei, br, _interpret(interpret))
    return y[:m].reshape(*lead, n)


# ---------------------------------------------------------------------------
# Standalone CGEMM
# ---------------------------------------------------------------------------
def cgemm(ar: jax.Array, ai: jax.Array, br: jax.Array, bi: jax.Array, *,
          path: str = "pallas", bm: int = 128, bn: int = 128, bk: int = 128,
          interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """(M,K)x(K,N) complex matmul."""
    if path in ("ref", "xla"):
        return ref_k.ref_cgemm(ar, ai, br, bi)
    m, k = ar.shape
    _, n = br.shape
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    mp, np_, kp = _rup(m, bm), _rup(n, bn), _rup(k, bk)
    pad2 = lambda a, r, c: _pad_axis(_pad_axis(a, 0, r), 1, c)
    cr, ci = cgemm_k.cgemm_call(
        pad2(ar, mp, kp), pad2(ai, mp, kp), pad2(br, kp, np_),
        pad2(bi, kp, np_), bm=bm, bn=bn, bk=bk,
        interpret=_interpret(interpret))
    return cr[:m, :n], ci[:m, :n]


# ---------------------------------------------------------------------------
# Fused FNO spectral layers (the paper's contribution)
#
# The pallas path is wrapped in jax.custom_vjp so training can stay on the
# fused kernels end-to-end. The layer is y = Re(((x·C)∘W)·E) — real-linear
# in both x and W — so:
#   * dx is the SAME fused DFT→CGEMM→iDFT pipeline run on the cotangent
#     with transposed DFT operands (spectral.*_adjoint_mats) and the weight
#     swapped over (out, hidden);
#   * dW is the fused rank-reduction kernel (fused_fno*_wgrad_call):
#     conj(Σ_b Ĝ·A) with both spectra computed in-kernel.
# ---------------------------------------------------------------------------
def _mats_1d(n: int, modes: int, kp: int, dtype, adjoint: bool = False):
    if adjoint:
        cr, ci = spectral.irdft_adjoint_mats(n, modes)  # [n, modes]
        er, ei = spectral.rdft_adjoint_mats(n, modes)   # [modes, n]
    else:
        cr, ci = spectral.rdft_mats(n, modes)
        er, ei = spectral.irdft_mats(n, modes)
    pad_c = lambda a: _pad_axis(jnp.asarray(a, dtype), 1, kp)
    pad_e = lambda a: _pad_axis(jnp.asarray(a, dtype), 0, kp)
    return pad_c(cr), pad_c(ci), pad_e(er), pad_e(ei)


def _fno1d_fused(x, wr, wi, modes, bb, bo, bh, interpret,
                 adjoint: bool = False):
    """Pad to block multiples and invoke the fused 1D kernel.

    adjoint=True runs the input-cotangent pipeline: transposed DFT
    operands; the caller passes (out, hidden)-swapped weights.
    """
    b, h, n = x.shape
    o = wr.shape[0]
    per_mode = wr.ndim == 3
    kp = _rup(modes, 128)
    bb, bo, bh, bp, op_, hp = _blocks(x, o, bb, bo, bh)
    cr, ci, er, ei = _mats_1d(n, modes, kp, x.dtype, adjoint)
    xpad = _pad_axis(_pad_axis(x, 0, bp), 1, hp)
    wpad = lambda w: _pad_axis(_pad_axis(
        (_pad_axis(w, 2, kp) if per_mode else w), 0, op_), 1, hp)
    y = f1d.fused_fno1d_call(xpad, wpad(wr), wpad(wi), cr, ci, er, ei,
                             bb=bb, bo=bo, bh=bh, interpret=interpret)
    return y[:b, :o]


def _fno1d_wgrad(x, gy, modes, bb, bo, bh, interpret, per_mode):
    """Fused weight cotangent: [B,H,K]ᴴ·[B,O,K] rank reduction."""
    b, h, n = x.shape
    o = gy.shape[1]
    kp = _rup(modes, 128)
    bb, bo, bh, bp, op_, hp = _blocks(x, o, bb, bo, bh)
    dtype = x.dtype
    cr, ci = spectral.rdft_mats(n, modes)
    etr, eti = spectral.irdft_adjoint_mats(n, modes)
    pad_c = lambda a: _pad_axis(jnp.asarray(a, dtype), 1, kp)
    xpad = _pad_axis(_pad_axis(x, 0, bp), 1, hp)
    gpad = _pad_axis(_pad_axis(gy, 0, bp), 1, op_)
    dwr, dwi = f1d.fused_fno1d_wgrad_call(
        xpad, gpad, pad_c(cr), pad_c(ci), pad_c(etr), pad_c(eti),
        bb=bb, bo=bo, bh=bh, per_mode=per_mode, interpret=interpret)
    if per_mode:  # kernel emits [K,O,H]
        return (jnp.transpose(dwr, (1, 2, 0))[:o, :h, :modes],
                jnp.transpose(dwi, (1, 2, 0))[:o, :h, :modes])
    return dwr[:o, :h], dwi[:o, :h]


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _spectral_layer_1d_pallas(x, wr, wi, modes, bb, bo, bh, interpret):
    return _fno1d_fused(x, wr, wi, modes, bb, bo, bh, interpret)


def _fno1d_vjp_fwd(x, wr, wi, modes, bb, bo, bh, interpret):
    y = _fno1d_fused(x, wr, wi, modes, bb, bo, bh, interpret)
    return y, (x, wr, wi)


def _fno1d_vjp_bwd(modes, bb, bo, bh, interpret, res, gy):
    x, wr, wi = res
    gy = gy.astype(x.dtype)
    dx = _fno1d_fused(gy, jnp.swapaxes(wr, 0, 1), jnp.swapaxes(wi, 0, 1),
                      modes, bb, bo, bh, interpret, adjoint=True)
    dwr, dwi = _fno1d_wgrad(x, gy, modes, bb, bo, bh, interpret,
                            per_mode=wr.ndim == 3)
    return (dx.astype(x.dtype), dwr.astype(wr.dtype), dwi.astype(wi.dtype))


_spectral_layer_1d_pallas.defvjp(_fno1d_vjp_fwd, _fno1d_vjp_bwd)


def spectral_layer_1d(x: jax.Array, wr: jax.Array, wi: jax.Array,
                      modes: int, *, path: str = "pallas",
                      bb: int = 8, bo: int = 128, bh: int = 128,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Full 1D FNO spectral layer. x: [B,H,N]; w: [O,H] or [O,H,modes].

    path="pallas" is differentiable: jax.grad routes through the fused
    backward kernels (custom_vjp), never falling back to XLA.
    """
    if path == "ref":
        return ref_k.ref_fno1d(x, wr, wi, modes)
    n = x.shape[-1]
    if path == "xla":
        xr, xi = spectral.truncated_rdft(x, modes)
        eq = "oh,bhm->bom" if wr.ndim == 2 else "ohm,bhm->bom"
        yr = jnp.einsum(eq, wr, xr) - jnp.einsum(eq, wi, xi)
        yi = jnp.einsum(eq, wr, xi) + jnp.einsum(eq, wi, xr)
        return spectral.padded_irdft(yr, yi, n)
    return _spectral_layer_1d_pallas(x, wr, wi, modes, bb, bo, bh,
                                     _interpret(interpret))


def _mats_2d(nx: int, ny: int, kx: int, ky: int, dtype,
             adjoint: bool = False):
    if adjoint:
        cr, ci = spectral.irdft_adjoint_mats(ny, ky)        # Eᵀ [ny,ky]
        fr, fi = spectral.cdft_adjoint_mats(nx, kx, True)   # G⁻ᵀ [nx,kx]
        gr, gi = spectral.cdft_adjoint_mats(nx, kx, False)  # Fᵀ [kx,nx]
        er, ei = spectral.rdft_adjoint_mats(ny, ky)         # Cᵀ [ky,ny]
    else:
        cr, ci = spectral.rdft_mats(ny, ky)  # stage-1: rDFT along Y
        fr, fi = spectral.cdft_mats(nx, kx, False)  # stage-2: cDFT along X
        gr, gi = spectral.cdft_mats(nx, kx, True)  # inverse cDFT along X
        er, ei = spectral.irdft_mats(ny, ky)  # inverse rDFT along Y
    j = lambda a: jnp.asarray(a, dtype)
    return (j(cr), j(ci), j(fr), j(fi), j(gr), j(gi), j(er), j(ei))


def _fno2d_full_fused(x, wr, wi, modes, bb, bo, bh, interpret,
                      adjoint: bool = False):
    """Pad and invoke the fully fused 2D kernel (forward or, with
    adjoint=True and swapped weights, the input-cotangent pipeline)."""
    kx, ky = modes
    nx, ny = x.shape[-2:]
    b, h = x.shape[:2]
    o = wr.shape[0]
    bb, bo, bh, bp, op_, hp = _blocks(x, o, bb, bo, bh)
    xpad = _pad_axis(_pad_axis(x, 0, bp), 1, hp)
    mats = _mats_2d(nx, ny, kx, ky, x.dtype, adjoint)
    wpad = lambda w: _pad_axis(_pad_axis(w, 0, op_), 1, hp)
    y = f2d.fused_fno2d_full_call(xpad, wpad(wr), wpad(wi), *mats,
                                  bb=bb, bo=bo, bh=bh, interpret=interpret)
    return y[:b, :o]


def _fno2d_wgrad(x, gy, modes, bb, bo, bh, interpret, per_mode):
    """Fused 2D weight cotangent: conj(Σ_b Ĝ·A) rank reduction."""
    kx, ky = modes
    b, h, nx, ny = x.shape
    o = gy.shape[1]
    bb, bo, bh, bp, op_, hp = _blocks(x, o, bb, bo, bh)
    dtype = x.dtype
    j = lambda a: jnp.asarray(a, dtype)
    cr, ci = spectral.rdft_mats(ny, ky)
    fr, fi = spectral.cdft_mats(nx, kx, False)
    etr, eti = spectral.irdft_adjoint_mats(ny, ky)
    gtr, gti = spectral.cdft_adjoint_mats(nx, kx, True)
    xpad = _pad_axis(_pad_axis(x, 0, bp), 1, hp)
    gpad = _pad_axis(_pad_axis(gy, 0, bp), 1, op_)
    dwr, dwi = f2d.fused_fno2d_wgrad_call(
        xpad, gpad, j(cr), j(ci), j(fr), j(fi), j(etr), j(eti), j(gtr),
        j(gti), bb=bb, bo=bo, bh=bh, per_mode=per_mode, interpret=interpret)
    if per_mode:  # kernel emits [KY,KX,O,H] -> [O,H,KX,KY]
        return (jnp.transpose(dwr, (2, 3, 1, 0))[:o, :h],
                jnp.transpose(dwi, (2, 3, 1, 0))[:o, :h])
    return dwr[:o, :h], dwi[:o, :h]


def _fno2d_pallas_impl(x, wr, wi, modes, variant, bb, bo, bh, interpret):
    if variant == "full":
        return _fno2d_full_fused(x, wr, wi, modes, bb, bo, bh, interpret)
    # paper-faithful partial fusion: stage-1 truncated rDFT as separate
    # kernel, then [cDFT_X → CGEMM → icDFT_X] fused, then separate irDFT.
    kx, ky = modes
    nx, ny = x.shape[-2:]
    b, h = x.shape[:2]
    o = wr.shape[0]
    bb, bo, bh, bp, op_, hp = _blocks(x, o, bb, bo, bh)
    xpad = _pad_axis(_pad_axis(x, 0, bp), 1, hp)
    _, _, fr, fi, gr, gi, _, _ = _mats_2d(nx, ny, kx, ky, x.dtype)
    wpad = lambda w: _pad_axis(_pad_axis(w, 0, op_), 1, hp)
    zr, zi = truncated_rdft(xpad, ky, path="pallas", interpret=interpret)
    yr, yi = f2d.fused_fno2d_call(zr, zi, wpad(wr), wpad(wi), fr, fi, gr, gi,
                                  bb=bb, bo=bo, bh=bh, interpret=interpret)
    # y pair [B,KY,O,X] -> [B,O,X,KY], then final padded irDFT along Y.
    yr = jnp.transpose(yr[:b, :, :o], (0, 2, 3, 1))
    yi = jnp.transpose(yi[:b, :, :o], (0, 2, 3, 1))
    return padded_irdft(yr, yi, ny, path="pallas", interpret=interpret)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _spectral_layer_2d_pallas(x, wr, wi, modes, variant, bb, bo, bh,
                              interpret):
    return _fno2d_pallas_impl(x, wr, wi, modes, variant, bb, bo, bh,
                              interpret)


def _fno2d_vjp_fwd(x, wr, wi, modes, variant, bb, bo, bh, interpret):
    y = _fno2d_pallas_impl(x, wr, wi, modes, variant, bb, bo, bh, interpret)
    return y, (x, wr, wi)


def _fno2d_vjp_bwd(modes, variant, bb, bo, bh, interpret, res, gy):
    # partial and full compute the same linear map, so one adjoint (the
    # fully fused one) serves both variants.
    x, wr, wi = res
    gy = gy.astype(x.dtype)
    dx = _fno2d_full_fused(gy, jnp.swapaxes(wr, 0, 1),
                           jnp.swapaxes(wi, 0, 1), modes, bb, bo, bh,
                           interpret, adjoint=True)
    dwr, dwi = _fno2d_wgrad(x, gy, modes, bb, bo, bh, interpret,
                            per_mode=wr.ndim == 4)
    return (dx.astype(x.dtype), dwr.astype(wr.dtype), dwi.astype(wi.dtype))


_spectral_layer_2d_pallas.defvjp(_fno2d_vjp_fwd, _fno2d_vjp_bwd)


def spectral_layer_2d(x: jax.Array, wr: jax.Array, wi: jax.Array,
                      modes: Tuple[int, int], *, path: str = "pallas",
                      variant: str = "full", bb: int = 2, bo: int = 128,
                      bh: int = 32,
                      interpret: Optional[bool] = None) -> jax.Array:
    """Full 2D FNO spectral layer, TurboFNO truncation convention.

    x: [B,H,X,Y]; w: [O,H] or [O,H,kx,ky]. variant: "partial" fuses only
    around the CGEMM (paper-faithful); "full" fuses the entire layer
    (beyond-paper, DESIGN.md §3.4). path="pallas" is differentiable via
    custom_vjp (fused backward for both variants).
    """
    kx, ky = modes
    if path == "ref":
        return ref_k.ref_fno2d(x, wr, wi, modes)
    nx, ny = x.shape[-2:]
    per_mode = wr.ndim == 4
    if path == "xla":
        zr, zi = spectral.truncated_rdft(x, ky)  # [B,H,X,ky]
        zr, zi = jnp.swapaxes(zr, -1, -2), jnp.swapaxes(zi, -1, -2)
        ar, ai = spectral.truncated_cdft(zr, zi, kx)  # [B,H,ky,kx]
        eq = "oh,bhyx->boyx" if not per_mode else "ohxy,bhyx->boyx"
        yr = jnp.einsum(eq, wr, ar) - jnp.einsum(eq, wi, ai)
        yi = jnp.einsum(eq, wr, ai) + jnp.einsum(eq, wi, ar)
        tr, ti = spectral.padded_icdft(yr, yi, nx)  # [B,O,ky,X]
        tr, ti = jnp.swapaxes(tr, -1, -2), jnp.swapaxes(ti, -1, -2)
        yr2 = spectral.padded_irdft(tr, ti, ny)  # real [B,O,X,Y]
        return yr2

    if variant != "full" and per_mode:
        raise NotImplementedError(
            "paper-faithful partial fusion implements the paper's shared-"
            "weight CGEMM; use variant='full' or path='xla' for per_mode")
    return _spectral_layer_2d_pallas(x, wr, wi, modes, variant, bb, bo, bh,
                                     _interpret(interpret))
