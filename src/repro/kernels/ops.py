"""Public jit'd wrappers around the Pallas kernels.

Handles (8,128)-alignment padding, block-size selection, and path dispatch:

  path="ref"    — jnp.fft staged oracle (the "PyTorch baseline")
  path="xla"    — truncated-DFT matmul formulation, fused by XLA (runs on
                  any backend; this is what the distributed dry-run lowers)
  path="pallas" — the fused TurboFNO kernels (interpret=True off-TPU)

Padding rules: modes K and channel dims are padded with zeros — padded DFT
rows/weight entries contribute exactly zero through the linear pipeline, so
results are sliced back without error.

Mixed precision: the spectral layers take an optional PrecisionPolicy. The
compute-dtype casts live inside the custom_vjp, so the caller's primal and
cotangent dtypes are preserved while the kernels run at the policy's
compute dtype with f32 accumulators (ROADMAP.md §Precision policy).
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PrecisionPolicy
from repro.core import spectral
from repro.kernels import cgemm as cgemm_k
from repro.kernels import dft as dft_k
from repro.kernels import engine
from repro.kernels import ref as ref_k
from repro.tuning import resolve_launch_plans
from repro.tuning.plans import LaunchPlans


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag: Optional[bool]) -> bool:
    return (not on_tpu()) if flag is None else flag


def _rup(v: int, m: int) -> int:
    return ((v + m - 1) // m) * m


def _pad_axis(x: jax.Array, axis: int, to: int) -> jax.Array:
    pad = to - x.shape[axis]
    if pad == 0:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


@functools.lru_cache(maxsize=None)
def _pick_block(dim: int, pref: int) -> int:
    """Clamp a preferred block size to the actual dim with minimal pad
    waste: among the feasible candidates (8-aligned sizes up to pref, or
    every size up to pref when pref < 8 — batch blocks), pick the one
    whose padded total ``_rup(dim, b)`` is smallest, breaking ties toward
    the larger block (fewer grid steps). This keeps prime/odd extents
    from forcing near-2× padding — e.g. dim=129 under pref=128 pads to
    136 via b=8, not to 256 via b=128 — while exact-fit dims still get
    the largest divisor ≤ pref."""
    if pref < 8:
        cands = range(1, pref + 1)
    else:
        cands = range(8, max(8, min(pref, _rup(dim, 8))) + 1, 8)
    return min(cands, key=lambda b: (_rup(dim, b), -b))


def _blocks(x, o, bb, bo, bh):
    """Resolve (bb,bo,bh) block sizes and padded (B,O,H) for x[B,H,...]."""
    b, h = x.shape[:2]
    bb = _pick_block(b, bb)
    bo = _pick_block(o, bo)
    bh = _pick_block(h, bh)
    return bb, bo, bh, _rup(b, bb), _rup(o, bo), _rup(h, bh)


# ---------------------------------------------------------------------------
# Standalone truncated-DFT kernels (paper §3.3 — FFT w/ built-in filtering)
#
# All four transforms share one shape recipe: flatten the leading dims to
# rows, lane-align the modes axis to 128 (forward operands pad columns,
# inverse operands pad rows — and the inverse *inputs* pad their modes
# axis to match), row-block, invoke the dft.py kernel, un-pad. `_rowwise`
# holds that recipe once; each wrapper only picks the operand factory,
# kernel, and path dispatch.
# ---------------------------------------------------------------------------
def _rowwise(call, rows, mats, out_modes: int, block_rows: int,
             interpret: Optional[bool], pad_in_to: int = 0):
    """Run a row-blocked standalone DFT kernel.

    rows: input arrays [..., K_in] sharing leading dims; mats: broadcast
    DFT operands; out_modes: slice of the kernel's last dim to keep (0 =
    keep all); pad_in_to: zero-pad the inputs' last axis first (inverse
    transforms whose operands were row-padded).
    """
    lead = rows[0].shape[:-1]
    m = int(np.prod(lead)) if lead else 1
    br = _pick_block(m, block_rows)
    mp = _rup(m, br)
    if pad_in_to:
        rows = [_pad_axis(r, -1, pad_in_to) for r in rows]
    rows2d = [_pad_axis(r.reshape(m, r.shape[-1]), 0, mp) for r in rows]
    out = call(*rows2d, *mats, br, _interpret(interpret))
    single = not isinstance(out, (tuple, list))
    outs = (out,) if single else tuple(out)
    outs = tuple(o[:m, :out_modes or o.shape[-1]].reshape(
        *lead, out_modes or o.shape[-1]) for o in outs)
    return outs[0] if single else outs


def _dft_operands(mats, dtype, pad_axis: int, to: int):
    return tuple(_pad_axis(jnp.asarray(a, dtype), pad_axis, to)
                 for a in mats)


def truncated_rdft(x: jax.Array, modes: int, *, path: str = "pallas",
                   block_rows: int = 256,
                   interpret: Optional[bool] = None,
                   operand_dtype: Optional[str] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """rFFT along the last axis keeping `modes` bins. x: [..., N].

    operand_dtype overrides the DFT-matrix dtype (defaults to x.dtype;
    PrecisionPolicy.spectral_dtype on the partial-fusion path)."""
    if path == "ref":
        return ref_k.ref_truncated_rdft(x, modes)
    if path == "xla":
        return spectral.truncated_rdft(x, modes)
    mats = _dft_operands(spectral.rdft_mats(x.shape[-1], modes),
                         operand_dtype or x.dtype, 1, _rup(modes, 128))
    return _rowwise(dft_k._rdft_call, [x], mats, modes, block_rows,
                    interpret)


def padded_irdft(xr: jax.Array, xi: jax.Array, n: int, *,
                 path: str = "pallas", block_rows: int = 256,
                 interpret: Optional[bool] = None,
                 operand_dtype: Optional[str] = None) -> jax.Array:
    """Inverse rFFT from `modes` bins zero-padded to length n."""
    if path == "ref":
        return ref_k.ref_padded_irdft(xr, xi, n)
    if path == "xla":
        return spectral.padded_irdft(xr, xi, n)
    kp = _rup(xr.shape[-1], 128)
    mats = _dft_operands(spectral.irdft_mats(n, xr.shape[-1]),
                         operand_dtype or xr.dtype, 0, kp)
    return _rowwise(dft_k._irdft_call, [xr, xi], mats, 0, block_rows,
                    interpret, pad_in_to=kp)


def truncated_cdft(xr: jax.Array, xi: jax.Array, modes: int, *,
                   path: str = "pallas", block_rows: int = 256,
                   interpret: Optional[bool] = None,
                   operand_dtype: Optional[str] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """Complex DFT along the last axis keeping the first `modes` bins.

    operand_dtype overrides the DFT-matrix dtype (defaults to xr.dtype;
    PrecisionPolicy.spectral_dtype on the partial-fusion path — the same
    contract the real-input wrappers above already honor)."""
    if path == "ref":
        return ref_k.ref_truncated_cdft(xr, xi, modes)
    if path == "xla":
        return spectral.truncated_cdft(xr, xi, modes)
    mats = _dft_operands(spectral.cdft_mats(xr.shape[-1], modes),
                         operand_dtype or xr.dtype, 1, _rup(modes, 128))
    return _rowwise(dft_k._cdft_call, [xr, xi], mats, modes, block_rows,
                    interpret)


def padded_icdft(xr: jax.Array, xi: jax.Array, n: int, *,
                 path: str = "pallas", block_rows: int = 256,
                 interpret: Optional[bool] = None,
                 operand_dtype: Optional[str] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Inverse complex DFT from first-`modes` bins zero-padded to n.

    operand_dtype: see ``truncated_cdft``."""
    if path == "ref":
        return ref_k.ref_padded_icdft(xr, xi, n)
    if path == "xla":
        return spectral.padded_icdft(xr, xi, n)
    kp = _rup(xr.shape[-1], 128)
    mats = _dft_operands(spectral.cdft_mats(n, xr.shape[-1], True),
                         operand_dtype or xr.dtype, 0, kp)
    return _rowwise(dft_k._cdft_call, [xr, xi], mats, 0, block_rows,
                    interpret, pad_in_to=kp)


# ---------------------------------------------------------------------------
# Standalone CGEMM
# ---------------------------------------------------------------------------
def cgemm(ar: jax.Array, ai: jax.Array, br: jax.Array, bi: jax.Array, *,
          path: str = "pallas", bm: int = 128, bn: int = 128, bk: int = 128,
          interpret: Optional[bool] = None) -> Tuple[jax.Array, jax.Array]:
    """(M,K)x(K,N) complex matmul."""
    if path in ("ref", "xla"):
        return ref_k.ref_cgemm(ar, ai, br, bi)
    m, k = ar.shape
    _, n = br.shape
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    mp, np_, kp = _rup(m, bm), _rup(n, bn), _rup(k, bk)
    pad2 = lambda a, r, c: _pad_axis(_pad_axis(a, 0, r), 1, c)
    cr, ci = cgemm_k.cgemm_call(
        pad2(ar, mp, kp), pad2(ai, mp, kp), pad2(br, kp, np_),
        pad2(bi, kp, np_), bm=bm, bn=bn, bk=bk,
        interpret=_interpret(interpret))
    return cr[:m, :n], ci[:m, :n]


# ---------------------------------------------------------------------------
# Fused FNO spectral layers (the paper's contribution), rank-generic.
#
# One implementation serves every spatial rank: the engine
# (kernels/engine.py) emits the fused forward, adjoint, and weight-gradient
# pallas_calls for any R, and the helpers below only handle padding, block
# selection, and operand caching. spectral_layer_1d/2d/3d are thin
# rank-pinning wrappers.
#
# The pallas path is wrapped in jax.custom_vjp so training can stay on the
# fused kernels end-to-end. The layer is y = Re(((x·C…)∘W)·…E) — real-
# linear in both x and W — so:
#   * dx is the SAME fused DFT→CGEMM→iDFT pipeline run on the cotangent
#     with transposed DFT operands (spectral.fused_operand_mats
#     adjoint=True) and the weight swapped over (out, hidden);
#   * dW is the fused rank-reduction kernel (engine.fused_fnond_wgrad_call):
#     conj(Σ_b Ĝ·A) with both spectra computed in-kernel.
# ---------------------------------------------------------------------------
def _modes_key(modes) -> Tuple[int, ...]:
    return tuple(int(m) for m in modes)


def _mode_pad(modes: Sequence[int]) -> int:
    """Rank-1 keeps its modes axis lane-aligned (it is the minor dim of the
    accumulator); higher ranks use whole-extent mode blocks unpadded."""
    return _rup(modes[0], 128) if len(modes) == 1 else 0


def _default_policy(x, wr) -> PrecisionPolicy:
    """Policy inferred from the operands (legacy behavior): compute and
    spectral operands at x.dtype, dW at the weight dtype, f32 accumulate."""
    xd = jnp.dtype(x.dtype).name
    return PrecisionPolicy(param_dtype=jnp.dtype(wr.dtype).name,
                           compute_dtype=xd, spectral_dtype=xd)


def _fnond_fused(x, wr, wi, modes, bb, bo, bh, interpret, pol,
                 adjoint: bool = False, out_dtype: str = None,
                 wb=None, bias=None, gy=None, act: str = "linear"):
    """Pad to block multiples and invoke the rank-generic fused kernel.

    adjoint=True runs the input-cotangent pipeline: transposed DFT
    operands; the caller passes (out, hidden)-swapped weights. out_dtype
    overrides the emission dtype (backward emits dx at the primal dtype
    straight from the accumulator). wb [O,H] / bias [O] / act extend the
    kernel with the block epilogue (bypass GEMM riding the k-loop,
    +bias → activation at the ref write); gy feeds the "gelu_vjp"
    backward-recompute epilogue.
    """
    r = len(modes)
    b, h = x.shape[:2]
    o = wr.shape[0]
    per_mode = wr.ndim == 2 + r
    kp = _mode_pad(modes)
    bb, bo, bh, bp, op_, hp = _blocks(x, o, bb, bo, bh)
    mats = spectral.fused_operand_mats(
        tuple(x.shape[2:]), _modes_key(modes), pol.spectral_dtype,
        adjoint, kp)
    xpad = _pad_axis(_pad_axis(x, 0, bp), 1, hp)

    def wpad(w):
        if per_mode and kp:
            w = _pad_axis(w, 2, kp)
        return _pad_axis(_pad_axis(w, 0, op_), 1, hp)

    wbp = None if wb is None else _pad_axis(_pad_axis(wb, 0, op_), 1, hp)
    biasp = None if bias is None else _pad_axis(bias[:, None], 0, op_)
    gyp = None if gy is None else _pad_axis(_pad_axis(gy, 0, bp), 1, op_)
    y = engine.fused_fnond_call(xpad, wpad(wr), wpad(wi), *mats,
                                bb=bb, bo=bo, bh=bh, interpret=interpret,
                                out_dtype=out_dtype,
                                acc_dtype=pol.accum_dtype,
                                wb=wbp, bias=biasp, gy=gyp, act=act)
    return y[:b, :o]


def _outer_fwd_batched(x, spatial, modes, interpret, operand_dtype=None,
                       block_rows=256):
    """All outer forward stages (axes s_2..s_R) in ONE kernel launch.

    The separable outer transforms collapse into a single matmul with the
    Kronecker-combined operand (spectral.outer_fwd_mats) instead of one
    standalone DFT launch per axis. x: [B,H,s_1..s_R] real; returns the
    pair [B,H,s_1,K_R..K_2]."""
    r = len(spatial)
    ok = tuple(modes[1:])
    kk = int(np.prod(ok))
    mats = _dft_operands(
        spectral.outer_fwd_mats(tuple(spatial[1:]), ok),
        operand_dtype or x.dtype, 1, _rup(kk, 128))
    lead = x.shape[:3]
    xf = x.reshape(*lead, -1)
    zr, zi = _rowwise(dft_k._rdft_call, [xf], mats, kk, block_rows,
                      interpret)
    shape = lead + tuple(modes[r - 1:0:-1])  # (K_R .. K_2)
    return zr.reshape(shape), zi.reshape(shape)


def _outer_inv_batched(tr, ti, spatial, interpret, operand_dtype=None,
                       block_rows=256):
    """All outer inverse stages in one launch (adjoint of
    _outer_fwd_batched): t [B,O,s_1,K_R..K_2] complex pair → real
    [B,O,s_1,s_2..s_R] via the combined padded-inverse operand."""
    ok = tuple(tr.shape[3:][::-1])  # trailing (K_R..K_2) → (k_2..k_R)
    kk = int(np.prod(ok))
    kp = _rup(kk, 128)
    mats = _dft_operands(
        spectral.outer_inv_mats(tuple(spatial[1:]), ok),
        operand_dtype or tr.dtype, 0, kp)
    lead = tr.shape[:3]
    flat = lambda t: t.reshape(*lead, -1)
    y = _rowwise(dft_k._irdft_call, [flat(tr), flat(ti)], mats, 0,
                 block_rows, interpret, pad_in_to=kp)
    return y.reshape(lead + tuple(spatial[1:]))


def _fnond_partial(x, wr, wi, modes, bb, bo, bh, interpret, pol):
    """Paper-faithful partial fusion for rank R ≥ 2: the outer R-1 forward
    and inverse transforms run as standalone kernels (dft.py); only
    [cDFT_s1 → CGEMM → icDFT_s1] — the stages adjacent to the GEMM — are
    fused, matching TurboFNO §4.3. Rank 1 has no outer stages (partial ==
    full). Rank ≥ 3 batches all outer axes into one launch per direction
    (Kronecker-combined operands)."""
    r = len(modes)
    if r == 1:
        return _fnond_fused(x, wr, wi, modes, bb, bo, bh, interpret, pol)
    b, h = x.shape[:2]
    spatial = x.shape[2:]
    o = wr.shape[0]
    per_mode = wr.ndim == 2 + r
    bb, bo, bh, bp, op_, hp = _blocks(x, o, bb, bo, bh)
    xpad = _pad_axis(_pad_axis(x, 0, bp), 1, hp)

    # Outer forward stages: rank 2 is a single rDFT along s_2; rank ≥ 3
    # runs ALL outer axes (s_2..s_R) as one batched kernel launch. The
    # operands follow pol.spectral_dtype like the fused middle's.
    if r == 2:
        zr, zi = truncated_rdft(xpad, modes[-1], path="pallas",
                                interpret=interpret,
                                operand_dtype=pol.spectral_dtype)
    else:
        zr, zi = _outer_fwd_batched(xpad, spatial, modes, interpret,
                                    pol.spectral_dtype)

    # Fused middle on [B,H,s_1,K_R..K_2].
    mats = spectral.fused_operand_mats(
        tuple(spatial), _modes_key(modes), pol.spectral_dtype)
    fr, fi = mats[2 * r - 2], mats[2 * r - 1]  # forward cDFT along s_1
    gr, gi = mats[2 * r], mats[2 * r + 1]      # inverse cDFT along s_1
    wp = lambda w: _pad_axis(_pad_axis(w, 0, op_), 1, hp)
    yr, yi = engine.fused_fnond_core_call(
        zr, zi, wp(wr), wp(wi), fr, fi, gr, gi,
        bb=bb, bo=bo, bh=bh, interpret=interpret,
        acc_dtype=pol.accum_dtype)

    # Restore [B,O,s_1,K_R..K_2] layout and slice the channel padding.
    s = r - 1
    if per_mode:  # kernel emits [K_R..K_2, B, O, s_1]
        perm = (s, s + 1, s + 2) + tuple(range(s))
    else:  # kernel emits [B, K_R..K_2, O, s_1]
        perm = (0, s + 1, s + 2) + tuple(range(1, s + 1))
    tr = jnp.transpose(yr, perm)[:b, :o]
    ti = jnp.transpose(yi, perm)[:b, :o]

    # Outer inverse stages, mirrored: single irDFT at rank 2, one batched
    # launch at rank ≥ 3.
    if r == 2:
        return padded_irdft(tr, ti, spatial[-1], path="pallas",
                            interpret=interpret,
                            operand_dtype=pol.spectral_dtype)
    return _outer_inv_batched(tr, ti, spatial, interpret,
                              pol.spectral_dtype)


def _fnond_wgrad(x, gy, modes, bb, bo, bh, interpret, per_mode, pol,
                 out_dtype: str = None, with_bypass: bool = False):
    """Fused weight cotangent: conj(Σ_b Ĝ·A) rank reduction; dW emitted at
    out_dtype (the param dtype under mixed precision). with_bypass=True
    (fused-block backward) appends (dwb [O,H], dbias [O]) from the same
    kernel."""
    r = len(modes)
    b, h = x.shape[:2]
    o = gy.shape[1]
    kp = _mode_pad(modes)
    bb, bo, bh, bp, op_, hp = _blocks(x, o, bb, bo, bh)
    mats = spectral.wgrad_operand_mats(
        tuple(x.shape[2:]), _modes_key(modes), pol.spectral_dtype, kp)
    xpad = _pad_axis(_pad_axis(x, 0, bp), 1, hp)
    gpad = _pad_axis(_pad_axis(gy, 0, bp), 1, op_)
    out = engine.fused_fnond_wgrad_call(
        xpad, gpad, *mats, bb=bb, bo=bo, bh=bh, per_mode=per_mode,
        interpret=interpret, out_dtype=out_dtype,
        acc_dtype=pol.accum_dtype, with_bypass=with_bypass)
    dwr, dwi = out[:2]
    extra = (out[2][:o, :h], out[3][:o, 0]) if with_bypass else ()
    if per_mode:  # kernel emits [K_R..K_1,O,H] -> [O,H,K_1..K_R]
        perm = (r, r + 1) + tuple(range(r - 1, -1, -1))
        sl = (slice(o), slice(h)) + tuple(slice(m) for m in modes)
        return (jnp.transpose(dwr, perm)[sl],
                jnp.transpose(dwi, perm)[sl]) + extra
    return (dwr[:o, :h], dwi[:o, :h]) + extra


def _fnond_pallas_impl(x, wr, wi, modes, variant, plans, interpret, pol):
    # The compute-dtype casts live INSIDE the custom_vjp: primals (and
    # therefore the cotangents the caller sees) stay at the caller's
    # dtypes, while the kernels run at pol.compute_dtype. `plans` is the
    # per-launch LaunchPlans bundle (hashable nondiff arg): the forward
    # variants read fwd/core, the backward gz/dx/wgrad.
    cp = jnp.dtype(pol.compute_dtype)
    x, wr, wi = x.astype(cp), wr.astype(cp), wi.astype(cp)
    if variant == "full":
        return _fnond_fused(x, wr, wi, modes, *plans.fwd, interpret, pol)
    return _fnond_partial(x, wr, wi, modes, *plans.core, interpret, pol)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _spectral_layer_nd_pallas(x, wr, wi, modes, variant, plans, interpret,
                              pol):
    return _fnond_pallas_impl(x, wr, wi, modes, variant, plans, interpret,
                              pol)


def _fnond_vjp_fwd(x, wr, wi, modes, variant, plans, interpret, pol):
    y = _fnond_pallas_impl(x, wr, wi, modes, variant, plans, interpret,
                           pol)
    return y, (x, wr, wi)


def _fnond_vjp_bwd(modes, variant, plans, interpret, pol, res, gy):
    # partial and full compute the same linear map, so one adjoint (the
    # fully fused one) serves both variants. Mixed precision: operands run
    # at pol.compute_dtype, the accumulators at pol.accum_dtype (f32), and
    # the emissions happen once at the ref-write boundary — dx at the
    # primal x dtype, dW at the param dtype.
    x, wr, wi = res
    cp = jnp.dtype(pol.compute_dtype)
    gy = gy.astype(cp)
    wrc, wic = wr.astype(cp), wi.astype(cp)
    dx = _fnond_fused(gy, jnp.swapaxes(wrc, 0, 1), jnp.swapaxes(wic, 0, 1),
                      modes, *plans.dx, interpret, pol, adjoint=True,
                      out_dtype=jnp.dtype(x.dtype).name)
    dwr, dwi = _fnond_wgrad(x.astype(cp), gy, modes, *plans.wgrad,
                            interpret,
                            per_mode=wr.ndim == 2 + len(modes), pol=pol,
                            out_dtype=jnp.dtype(wr.dtype).name)
    return (dx.astype(x.dtype), dwr.astype(wr.dtype), dwi.astype(wi.dtype))


_spectral_layer_nd_pallas.defvjp(_fnond_vjp_fwd, _fnond_vjp_bwd)


def _fnond_xla(x, wr, wi, modes, pol=None):
    """Staged matmul formulation of the rank-R layer, fused by XLA.

    With a policy, operands are cast to the compute dtype first and the
    result is emitted at it — the parity reference for the pallas path at
    matching precision (accumulation stays f32 via preferred_element_type
    inside the spectral helpers)."""
    if pol is not None:
        cp = jnp.dtype(pol.compute_dtype)
        x, wr, wi = x.astype(cp), wr.astype(cp), wi.astype(cp)
    r = len(modes)
    spatial = x.shape[2:]
    per_mode = wr.ndim == 2 + r
    zr, zi = spectral.truncated_rdft(x, modes[-1])
    for j in range(1, r):  # cDFT along s_{R-1}…s_1 -> [B,H,K_R..K_1]
        zr = jnp.moveaxis(zr, -(j + 1), -1)
        zi = jnp.moveaxis(zi, -(j + 1), -1)
        zr, zi = spectral.truncated_cdft(zr, zi, modes[r - 1 - j])
    fwd = "uvw"[:r]           # K_1..K_R (the weight layout order)
    rev = fwd[::-1]           # K_R..K_1 (the spectrum layout order)
    eq = (f"oh{fwd},bh{rev}->bo{rev}" if per_mode
          else f"oh,bh{rev}->bo{rev}")
    yr = jnp.einsum(eq, wr, zr) - jnp.einsum(eq, wi, zi)
    yi = jnp.einsum(eq, wr, zi) + jnp.einsum(eq, wi, zr)
    for j in range(r - 1):  # icDFT along s_1…s_{R-1}
        yr, yi = spectral.padded_icdft(yr, yi, spatial[j])
        yr = jnp.moveaxis(yr, -1, 2 + j)
        yi = jnp.moveaxis(yi, -1, 2 + j)
    y = spectral.padded_irdft(yr, yi, spatial[-1])
    return y.astype(x.dtype) if pol is not None else y


# Per-rank (bb, bo, bh) kernel block-size defaults — the documented
# FALLBACK when no tuned cache entry matches a workload's tuning key.
# Block selection is owned by ``repro.tuning.resolve_launch_plans``
# (override → tuned cache → this table); nothing outside the resolver
# and the legacy ``analysis.vmem.resolve_blocks`` helper should read it.
_BLOCK_DEFAULTS = {1: (8, 128, 128), 2: (2, 128, 32), 3: (1, 128, 16)}


def _resolve_blocks(rank, bb, bo, bh):
    dbb, dbo, dbh = _BLOCK_DEFAULTS[rank]
    return bb or dbb, bo or dbo, bh or dbh


def _resolve_plans(x, wr, modes, pol, bb, bo, bh,
                   block_plan) -> LaunchPlans:
    """Per-launch block plans for this workload: the tuned-cache resolver
    keyed on (rank, shape class, layout, per-launch variant, dtype), with
    explicit nonzero bb/bo/bh (or an ``FNOConfig.block_plan`` triple)
    overriding component-wise and ``_BLOCK_DEFAULTS`` as the fallback."""
    override = tuple(block_plan) if block_plan else None
    plans = resolve_launch_plans(
        len(modes), hidden=x.shape[1], out=wr.shape[0],
        spatial=tuple(x.shape[2:]), modes=modes,
        per_mode=wr.ndim == 2 + len(modes), policy=pol,
        override=override)
    return plans.with_override(bb, bo, bh)


def _spectral_layer_nd(x, wr, wi, modes, path, variant, bb, bo, bh,
                       interpret, policy=None, block_plan=None):
    modes = _modes_key(modes)
    if path == "ref":
        if policy is not None:  # oracle runs in f32, emits at compute dtype
            y32 = ref_k.ref_fnond(x.astype(jnp.float32),
                                  wr.astype(jnp.float32),
                                  wi.astype(jnp.float32), modes)
            return y32.astype(policy.compute_dtype)
        return ref_k.ref_fnond(x, wr, wi, modes)
    if path == "xla":
        return _fnond_xla(x, wr, wi, modes, policy)
    pol = policy or _default_policy(x, wr)
    plans = _resolve_plans(x, wr, modes, pol, bb, bo, bh, block_plan)
    return _spectral_layer_nd_pallas(x, wr, wi, modes, variant, plans,
                                     _interpret(interpret), pol)


def spectral_layer_1d(x: jax.Array, wr: jax.Array, wi: jax.Array,
                      modes: int, *, path: str = "pallas",
                      bb: int = 0, bo: int = 0, bh: int = 0,
                      interpret: Optional[bool] = None,
                      policy: Optional[PrecisionPolicy] = None,
                      block_plan: Optional[Tuple[int, int, int]] = None
                      ) -> jax.Array:
    """Full 1D FNO spectral layer. x: [B,H,N]; w: [O,H] or [O,H,modes].

    path="pallas" is differentiable: jax.grad routes through the fused
    backward kernels (custom_vjp), never falling back to XLA. policy sets
    the mixed-precision contract (bf16 kernel I/O with f32 accumulators);
    None infers a uniform policy from the operand dtypes. Block sizes
    resolve through ``repro.tuning.resolve_launch_plans`` (tuned cache →
    ``_BLOCK_DEFAULTS``); nonzero bb/bo/bh or a ``block_plan`` triple
    override component-wise.
    """
    return _spectral_layer_nd(x, wr, wi, (modes,), path, "full", bb, bo, bh,
                              interpret, policy, block_plan)


def spectral_layer_2d(x: jax.Array, wr: jax.Array, wi: jax.Array,
                      modes: Tuple[int, int], *, path: str = "pallas",
                      variant: str = "full", bb: int = 0, bo: int = 0,
                      bh: int = 0,
                      interpret: Optional[bool] = None,
                      policy: Optional[PrecisionPolicy] = None,
                      block_plan: Optional[Tuple[int, int, int]] = None
                      ) -> jax.Array:
    """Full 2D FNO spectral layer, TurboFNO truncation convention.

    x: [B,H,X,Y]; w: [O,H] or [O,H,kx,ky]. variant: "partial" fuses only
    around the CGEMM (paper-faithful); "full" fuses the entire layer
    (beyond-paper, docs/DESIGN.md §3.4). path="pallas" is differentiable via
    custom_vjp (fused backward for both variants). policy / block
    selection: see spectral_layer_1d.
    """
    return _spectral_layer_nd(x, wr, wi, modes, path, variant, bb, bo, bh,
                              interpret, policy, block_plan)


def spectral_layer_3d(x: jax.Array, wr: jax.Array, wi: jax.Array,
                      modes: Tuple[int, int, int], *, path: str = "pallas",
                      variant: str = "full", bb: int = 0, bo: int = 0,
                      bh: int = 0,
                      interpret: Optional[bool] = None,
                      policy: Optional[PrecisionPolicy] = None,
                      block_plan: Optional[Tuple[int, int, int]] = None
                      ) -> jax.Array:
    """Full 3D FNO spectral layer (Navier–Stokes-class workloads).

    x: [B,H,X,Y,Z]; w: [O,H] or [O,H,kx,ky,kz]. Same engine, rank pinned
    to 3: variant "full" fuses the whole layer in one kernel; "partial"
    (paper-faithful) fuses only the GEMM-adjacent cDFT/icDFT pair and runs
    the outer transforms as ONE batched standalone launch per direction.
    path="pallas" is differentiable via custom_vjp (fused backward for
    both variants). policy / block selection: see spectral_layer_1d.
    """
    return _spectral_layer_nd(x, wr, wi, modes, path, variant, bb, bo, bh,
                              interpret, policy, block_plan)


# ---------------------------------------------------------------------------
# Fused FNO BLOCK (beyond the spectral layer): the standard FNO block
# y = gelu(spectral(x) + bypass(x) + bias) (Li et al. 2020) in ONE
# pallas_call on the full-fusion path. The 1×1 bypass conv contracts the
# same hidden axis as the engine's CGEMM k-loop, so it rides the existing
# grid into a third VMEM accumulator and +bias → +spectral → gelu happen
# in the iDFT epilogue — the per-layer XLA ops (bypass GEMM, bias, sum,
# GELU) and their ~4 HBM round trips on B·H·∏s tensors disappear.
#
# End-to-end differentiable via its own custom_vjp:
#   * gz: one fused kernel recomputes the pre-activation z through the
#     same forward pipeline and emits gz = gy·gelu'(z) (act="gelu_vjp") —
#     z never touches HBM;
#   * dx = spectral_adjoint(gz) + gz·W_b: the SAME block kernel with
#     adjoint DFT operands, (out,hidden)-swapped spectral weight, and the
#     transposed bypass riding the k-loop;
#   * dW, dW_b, dbias: the extended wgrad kernel (with_bypass=True) emits
#     all three from the refs it already holds in VMEM.
# The backward always runs the fully fused pipeline — partial and full
# compute the same function, so one adjoint serves both variants.
# ---------------------------------------------------------------------------
def _block_tail(s, x, wb, bias, out_dtype, act: str = "gelu"):
    """The staged block epilogue — XLA bypass GEMM + bias + activation on a
    spectral output s. Shared by the oracle paths AND the partial-variant
    pallas path so the parity target and the implementation can never
    diverge: z accumulates in f32, the single down-cast is the return."""
    byp = jnp.einsum("oh,bh...->bo...", wb.astype(x.dtype), x,
                     preferred_element_type=jnp.float32)
    z = (s.astype(jnp.float32) + byp
         + bias.astype(jnp.float32).reshape((1, -1) + (1,) * (x.ndim - 2)))
    if act == "gelu":
        z = jax.nn.gelu(z)
    return z.astype(out_dtype)


def _fno_block_oracle(x, wr, wi, wb, bias, modes, path, pol, act="gelu"):
    """Staged parity oracle: spectral layer (ref/xla path) + XLA bypass +
    bias + activation — the exact math the one-kernel pallas path fuses."""
    s = _spectral_layer_nd(x, wr, wi, modes, path, "full", 0, 0, 0,
                           None, pol)
    cp = jnp.dtype(pol.compute_dtype) if pol is not None else x.dtype
    return _block_tail(s, x.astype(cp), wb, bias, s.dtype, act)


def _fno_block_impl(x, wr, wi, wb, bias, modes, variant, plans,
                    interpret, pol, act, out_dtype):
    # Same cast contract as the spectral layer: compute-dtype casts live
    # inside the custom_vjp so the caller's primal/cotangent dtypes are
    # preserved (PrecisionPolicy — ROADMAP.md §Precision policy).
    # out_dtype (default: the compute dtype) overrides the single ref-write
    # emission — the TP-sharded dispatch keeps the partial pre-activations
    # at the accumulator dtype through the psum.
    cp = jnp.dtype(pol.compute_dtype)
    od = jnp.dtype(out_dtype) if out_dtype else cp
    x, wr, wi, wb, bias = (a.astype(cp) for a in (x, wr, wi, wb, bias))
    if variant == "full":
        return _fnond_fused(x, wr, wi, modes, *plans.fwd, interpret, pol,
                            wb=wb, bias=bias, act=act, out_dtype=od.name)
    # Paper-faithful partial fusion keeps the multi-kernel spectral
    # pipeline; the block tail (bypass+bias+act) runs as XLA ops. The
    # BACKWARD still uses the fully fused adjoint (one linear map).
    s = _fnond_partial(x, wr, wi, modes, *plans.core, interpret, pol)
    return _block_tail(s, x, wb, bias, od, act)


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(5, 6, 7, 8, 9, 10, 11))
def _fno_block_nd_pallas(x, wr, wi, wb, bias, modes, variant, plans,
                         interpret, pol, act, out_dtype):
    return _fno_block_impl(x, wr, wi, wb, bias, modes, variant, plans,
                           interpret, pol, act, out_dtype)


def _fno_block_vjp_fwd(x, wr, wi, wb, bias, modes, variant, plans,
                       interpret, pol, act, out_dtype):
    y = _fno_block_impl(x, wr, wi, wb, bias, modes, variant, plans,
                        interpret, pol, act, out_dtype)
    return y, (x, wr, wi, wb, bias)


def _fno_block_vjp_bwd(modes, variant, plans, interpret, pol, act,
                       out_dtype, res, gy):
    x, wr, wi, wb, bias = res
    cp = jnp.dtype(pol.compute_dtype)
    xc, wrc, wic, wbc, biasc = (a.astype(cp) for a in (x, wr, wi, wb, bias))
    gyc = gy.astype(cp)
    if act == "gelu":
        # (1) recompute the pre-activation through the fused forward and
        # form gz = gy·gelu'(z) in the epilogue — z never reaches HBM.
        gz = _fnond_fused(xc, wrc, wic, modes, *plans.gz, interpret, pol,
                          wb=wbc, bias=biasc, gy=gyc, act="gelu_vjp")
    else:
        # Linear block (the TP-sharded partial): z IS the output, so the
        # incoming cotangent is gz directly — no recompute kernel.
        gz = gyc
    # (2) dx = spectral_adjoint(gz) + gz·W_b: the same block kernel with
    # adjoint operands, swapped spectral weight, transposed bypass, linear
    # epilogue; dx emitted at the primal dtype from the f32 accumulator.
    dx = _fnond_fused(gz, jnp.swapaxes(wrc, 0, 1), jnp.swapaxes(wic, 0, 1),
                      modes, *plans.dx, interpret, pol, adjoint=True,
                      out_dtype=jnp.dtype(x.dtype).name,
                      wb=jnp.swapaxes(wbc, 0, 1))
    # (3) dW, dW_b, dbias from ONE extended wgrad kernel, emitted at the
    # param dtype straight from the f32 accumulators.
    dwr, dwi, dwb, db = _fnond_wgrad(
        xc, gz, modes, *plans.wgrad, interpret,
        per_mode=wr.ndim == 2 + len(modes), pol=pol,
        out_dtype=jnp.dtype(wr.dtype).name, with_bypass=True)
    return (dx.astype(x.dtype), dwr.astype(wr.dtype), dwi.astype(wi.dtype),
            dwb.astype(wb.dtype), db.astype(bias.dtype))


_fno_block_nd_pallas.defvjp(_fno_block_vjp_fwd, _fno_block_vjp_bwd)


def fno_block_nd(x: jax.Array, wr: jax.Array, wi: jax.Array, wb: jax.Array,
                 bias: jax.Array, modes: Sequence[int], *,
                 path: str = "pallas", variant: str = "full",
                 bb: int = 0, bo: int = 0, bh: int = 0,
                 interpret: Optional[bool] = None,
                 policy: Optional[PrecisionPolicy] = None,
                 act: str = "gelu",
                 out_dtype: Optional[str] = None,
                 block_plan: Optional[Tuple[int, int, int]] = None
                 ) -> jax.Array:
    """One whole FNO block: y = act(spectral(x) + x·W_bᵀ + bias).

    x: [B,H,s_1..s_R]; wr/wi: [O,H] or [O,H,k_1..k_R] spectral weight;
    wb: [O,H] bypass 1×1 conv (y_o += Σ_h x_h·wb[o,h]); bias: [O].

    path="pallas" + variant="full" lowers the ENTIRE block to one
    pallas_call, and jax.grad stays on fused kernels for all four
    cotangents (dx, dW, dW_b, dbias) via custom_vjp. variant="partial"
    keeps the paper-faithful multi-kernel spectral pipeline (XLA block
    tail) but shares the same fused backward. path="ref"/"xla" are the
    staged parity oracles. Block sizes come from the tuned-plan resolver
    (override → ``tuning/cache`` → ``_BLOCK_DEFAULTS``); nonzero bb/bo/bh
    or ``block_plan`` override component-wise across all five launches.
    policy: see spectral_layer_1d.

    act: "gelu" (the standard block) or "linear" (pre-activation only —
    the TP-sharded dispatch reduces partial pre-activations with a psum
    BEFORE the nonlinearity; its backward skips the gz-recompute kernel).

    out_dtype (pallas path only) overrides the ref-write emission dtype —
    the TP dispatch emits partials at the accumulator dtype so the psum
    stays f32 under the bf16 policy (ROADMAP.md §Precision policy).
    """
    modes = _modes_key(modes)
    assert act in ("gelu", "linear"), act
    if path in ("ref", "xla"):
        return _fno_block_oracle(x, wr, wi, wb, bias, modes, path, policy,
                                 act)
    pol = policy or _default_policy(x, wr)
    plans = _resolve_plans(x, wr, modes, pol, bb, bo, bh, block_plan)
    return _fno_block_nd_pallas(x, wr, wi, wb, bias, modes, variant, plans,
                                _interpret(interpret), pol, act, out_dtype)


# ---------------------------------------------------------------------------
# Fused MODEL ENDS (docs/DESIGN.md §6): the pointwise lifting MLP folded
# into the FIRST fused block kernel and the projection MLP into the LAST
# one. Both MLPs are channel-pointwise, so the lift rides the engine's
# hidden k-loop (each k step derives its hidden block from the raw input
# in VMEM) and the projection runs as the iDFT epilogue's tail — the
# lifted/projected activations, ~2·B·lift·∏s elements per step at the
# model boundary, never round-trip HBM. Forward is ONE pallas_call (so an
# ends-fused L-layer model still traces exactly L pallas_calls); the
# BACKWARD is the jax.vjp of the staged composition below — recompute-
# based, XLA-fused, sharing `_block_tail`/`_fnond_xla` with the parity
# oracles so the adjoint math can never diverge from the target.
#
# Scope: single-device and pure-DP dispatch only. Under TP the hidden
# k-loop is sharded — the lift's inner activation would have to be
# computed per-shard (replicated flops) and the projection consumes the
# FULL hidden vector, which only exists after the final layer's psum; the
# ends therefore stay staged XLA ops under TP (core.fno guards).
# ---------------------------------------------------------------------------
def _pointwise(w, b, x):
    """Channel-pointwise dense matching core.fno._dense: y follows x's
    dtype, the bias broadcast happens before the cast so its grad
    reduction accumulates upstream in f32."""
    y = jnp.einsum("bc...,cd->bd...", x, w.astype(x.dtype))
    bb_ = b.reshape((1, -1) + (1,) * (y.ndim - 2))
    return y + jnp.broadcast_to(bb_, y.shape).astype(x.dtype)


def _ends_staged(x, wr, wi, wb, bias, ends, modes, path, pol):
    """Staged lift → block → projection composition — the parity oracle
    for the ends-fused kernel AND its backward's recompute target."""
    lift, proj = ends
    h = x
    if lift is not None:
        l1w, l1b, l2w, l2b = lift
        h = jax.nn.gelu(_pointwise(l1w, l1b, h))
        h = _pointwise(l2w, l2b, h)
    z = _fno_block_oracle(h, wr, wi, wb, bias, modes, path, pol, "gelu")
    if proj is not None:
        p1w, p1b, p2w, p2b = proj
        z = jax.nn.gelu(_pointwise(p1w, p1b, z))
        z = _pointwise(p2w, p2b, z)
    return z


def _ends_fused_impl(x, wr, wi, wb, bias, ends, modes, plans, interpret,
                     pol):
    """Pad/transpose the end-MLP params to the engine layout and launch the
    single ends-fused kernel. Reuses the block_fwd tuned plan; the proj
    epilogue pins bo to the padded O (it contracts the full hidden width),
    so the out-channel grid collapses to one step."""
    cp = jnp.dtype(pol.compute_dtype)
    lift, proj = ends
    x, wr, wi, wb, bias = (a.astype(cp) for a in (x, wr, wi, wb, bias))
    lift = None if lift is None else tuple(a.astype(cp) for a in lift)
    proj = None if proj is None else tuple(a.astype(cp) for a in proj)
    r = len(modes)
    b = x.shape[0]
    o = wr.shape[0]
    h = lift[2].shape[1] if lift is not None else x.shape[1]
    kp = _mode_pad(modes)
    pbb, pbo, pbh = plans.fwd
    bb = _pick_block(b, pbb)
    bh = _pick_block(h, pbh)
    bp, hp = _rup(b, bb), _rup(h, bh)
    if proj is not None:
        bo = op_ = _rup(o, 8)
    else:
        bo = _pick_block(o, pbo)
        op_ = _rup(o, bo)
    mats = spectral.fused_operand_mats(
        tuple(x.shape[2:]), _modes_key(modes), pol.spectral_dtype, False,
        kp)

    def wpad(w):
        if wr.ndim == 2 + r and kp:
            w = _pad_axis(w, 2, kp)
        return _pad_axis(_pad_axis(w, 0, op_), 1, hp)

    wbp = _pad_axis(_pad_axis(wb, 0, op_), 1, hp)
    biasp = _pad_axis(bias[:, None], 0, op_)
    col = lambda v, to: _pad_axis(v[:, None], 0, to)
    mat = lambda w, rto, cto: _pad_axis(
        _pad_axis(jnp.swapaxes(w, 0, 1), 0, rto), 1, cto)
    engine_lift = None
    if lift is not None:
        l1w, l1b, l2w, l2b = lift
        cinp = _rup(x.shape[1], 8)
        lp = _rup(l1w.shape[1], 8)
        xpad = _pad_axis(_pad_axis(x, 0, bp), 1, cinp)
        engine_lift = (mat(l1w, lp, cinp), col(l1b, lp),
                       mat(l2w, hp, lp), col(l2b, hp))
    else:
        xpad = _pad_axis(_pad_axis(x, 0, bp), 1, hp)
    engine_proj = None
    if proj is not None:
        p1w, p1b, p2w, p2b = proj
        lp2 = _rup(p1w.shape[1], 8)
        coutp = _rup(p2w.shape[1], 8)
        engine_proj = (mat(p1w, lp2, op_), col(p1b, lp2),
                       mat(p2w, coutp, lp2), col(p2b, coutp))
    y = engine.fused_fnond_call(xpad, wpad(wr), wpad(wi), *mats,
                                bb=bb, bo=bo, bh=bh, interpret=interpret,
                                acc_dtype=pol.accum_dtype, wb=wbp,
                                bias=biasp, act="gelu", lift=engine_lift,
                                proj=engine_proj)
    cout = proj[3].shape[0] if proj is not None else o
    return y[:b, :cout]


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def _fno_block_ends_pallas(x, wr, wi, wb, bias, ends, modes, plans,
                           interpret, pol):
    return _ends_fused_impl(x, wr, wi, wb, bias, ends, modes, plans,
                            interpret, pol)


def _ends_vjp_fwd(x, wr, wi, wb, bias, ends, modes, plans, interpret, pol):
    y = _ends_fused_impl(x, wr, wi, wb, bias, ends, modes, plans,
                         interpret, pol)
    return y, (x, wr, wi, wb, bias, ends)


def _ends_vjp_bwd(modes, plans, interpret, pol, res, gy):
    # The staged composition is the adjoint target: jax.vjp recomputes the
    # forward through the XLA-fused staging (the same math the kernel
    # fuses) and transposes it — every cotangent lands at its primal's
    # dtype because the casts live inside `_ends_staged`'s callees.
    x, wr, wi, wb, bias, ends = res
    _, vjp = jax.vjp(
        lambda x_, wr_, wi_, wb_, b_, e_: _ends_staged(
            x_, wr_, wi_, wb_, b_, e_, modes, "xla", pol),
        x, wr, wi, wb, bias, ends)
    return vjp(gy.astype(jnp.dtype(pol.compute_dtype)))


_fno_block_ends_pallas.defvjp(_ends_vjp_fwd, _ends_vjp_bwd)


def fno_block_ends_nd(x: jax.Array, wr: jax.Array, wi: jax.Array,
                      wb: jax.Array, bias: jax.Array,
                      modes: Sequence[int], *,
                      lift: Optional[Tuple] = None,
                      proj: Optional[Tuple] = None,
                      path: str = "pallas", variant: str = "full",
                      bb: int = 0, bo: int = 0, bh: int = 0,
                      interpret: Optional[bool] = None,
                      policy: Optional[PrecisionPolicy] = None,
                      block_plan: Optional[Tuple[int, int, int]] = None
                      ) -> jax.Array:
    """``fno_block_nd`` with the model's end MLPs folded into the kernel.

    lift = (l1w [C_in,L], l1b [L], l2w [L,H], l2b [H]) — core.fno's
    lift1/lift2 params; x is then the RAW model input [B,C_in,s…].
    proj = (p1w [H,L], p1b [L], p2w [L,C_out], p2b [C_out]) — proj1/proj2;
    the result is the model output [B,C_out,s…]. Either end may be None
    (first vs last layer of a multi-layer model); both on a 1-layer model.

    path="pallas" runs ONE pallas_call (variant "full" only) and is
    differentiable: the custom_vjp backward is the jax.vjp of the staged
    composition — recompute-based, so nothing extra is saved for backward.
    path="ref"/"xla" are the staged parity oracles.
    """
    modes = _modes_key(modes)
    ends = (lift, proj)
    pol = policy or _default_policy(x, wr)
    if path in ("ref", "xla"):
        return _ends_staged(x, wr, wi, wb, bias, ends, modes, path, pol)
    assert variant == "full", \
        "fused ends require the full-fusion variant (partial stays staged)"
    hidden = lift[2].shape[1] if lift is not None else x.shape[1]
    override = tuple(block_plan) if block_plan else None
    plans = resolve_launch_plans(
        len(modes), hidden=hidden, out=wr.shape[0],
        spatial=tuple(x.shape[2:]), modes=modes,
        per_mode=wr.ndim == 2 + len(modes), policy=pol, override=override)
    plans = plans.with_override(bb, bo, bh)
    return _fno_block_ends_pallas(x, wr, wi, wb, bias, ends, modes, plans,
                                  _interpret(interpret), pol)


# ---------------------------------------------------------------------------
# DP×TP shard_map dispatch of the fused block (docs/DESIGN.md §6).
#
# DP shards the leading batch axis over `batch_axes`; TP shards the HIDDEN
# axis — the engine's k-loop contraction — over `model_axis`, so every
# shard runs the SAME fused kernel on its hidden slice and produces a
# partial pre-activation. Two layouts complete the sharded contraction:
#
#   tp_layout="scatter" (production): a psum_scatter over the model axis
#     emits the NEXT layer's hidden shard directly — (tp-1)/tp of the
#     tensor crosses the wire and the output lands already sharded
#     P(batch, model), so the inter-layer re-shard disappears. The
#     collective is ``sharding.scatter_sum`` — a custom_vjp whose backward
#     is the mirrored all_gather — so jax.grad stays end-to-end
#     differentiable through the scattered layout. tp_overlap=True runs
#     the same reduction as a ppermute ring (tp-1 async chunk hops XLA
#     can hide under neighboring k-loop compute).
#
#   tp_layout="psum" (legacy/final-layer): ONE lax.psum per layer on the
#     pre-activation — 2(tp-1)/tp wire bytes, replicated output. The FINAL
#     TP layer always uses this: the projection consumes the full hidden
#     vector, so there is no next shard to scatter into.
#
# Either way bias + GELU apply only after the cross-shard reduction (a
# nonlinearity cannot commute past a sharded contraction), as XLA ops on
# the reduced value while the kernel keeps act="linear". Every spec is
# guard_spec-ed: an axis that does not divide its dim degrades to
# replication instead of erroring.
# ---------------------------------------------------------------------------
def fno_block_nd_sharded(x: jax.Array, wr: jax.Array, wi: jax.Array,
                         wb: jax.Array, bias: jax.Array,
                         modes: Sequence[int], *, mesh,
                         batch_axes: Sequence[str] = ("data",),
                         model_axis: Optional[str] = "model",
                         variant: str = "full", bb: int = 0, bo: int = 0,
                         bh: int = 0, interpret: Optional[bool] = None,
                         policy: Optional[PrecisionPolicy] = None,
                         act: str = "gelu",
                         tp_layout: str = "psum",
                         tp_overlap: bool = False,
                         ends: Optional[Tuple] = None,
                         block_plan: Optional[Tuple[int, int, int]] = None
                         ) -> jax.Array:
    """``fno_block_nd`` under shard_map on a (DP×TP) mesh — the production
    dispatch behind ``core.spectral_conv.apply_fno_block_nd`` whenever a
    ``sharding_context`` is active. Fully differentiable: shard_map
    transposes the collectives for the backward (psum → replication;
    scatter_sum carries its own mirrored-all_gather custom_vjp), and each
    shard's backward stays on the fused adjoint/wgrad kernels.

    tp_layout: "psum" replicates the layer output (one all-reduce);
    "scatter" emits it sharded P(batch, model) over the hidden axis via
    psum_scatter — half the wire bytes; the caller threads "scatter" for
    interior TP layers and "psum" for the final one (core.fno.apply_fno).
    tp_overlap=True (scattered only) uses the ppermute-ring reduction.

    ends: optional (lift, proj) tuple for ``fno_block_ends_nd`` — pure-DP
    meshes only (the end params replicate across shards); core.fno keeps
    the ends staged whenever TP is on.
    """
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import (compat_shard_map, guard_spec,
                                            ring_scatter_sum, scatter_sum)

    assert tp_layout in ("psum", "scatter"), tp_layout
    modes = _modes_key(modes)
    r = len(modes)
    sp0 = (None,) * r
    pol = policy or _default_policy(x, wr)
    b_axes = tuple(a for a in batch_axes if a in mesh.shape)
    b_ent = (b_axes if len(b_axes) > 1 else b_axes[0]) if b_axes else None
    tp = mesh.shape.get(model_axis, 1) if model_axis else 1
    o = wr.shape[0]
    xspec = guard_spec(P(b_ent, model_axis if tp > 1 else None, *sp0),
                       x.shape, mesh)
    tp_on = tp > 1 and xspec[1] is not None
    # The scattered layout additionally needs the OUTPUT channel dim to
    # divide tp (each shard keeps 1/tp of it); degrade to psum otherwise.
    scatter = tp_layout == "scatter" and tp_on and o % tp == 0
    h_ent = model_axis if tp_on else None
    wspec = guard_spec(P(None, h_ent, *((None,) * (wr.ndim - 2))),
                       wr.shape, mesh)
    wbspec = guard_spec(P(None, h_ent), wb.shape, mesh)
    bspec = P(model_axis) if scatter else P(None)
    out_spec = P(xspec[0], model_axis if scatter else None, *sp0)
    kw = dict(variant=variant, bb=bb, bo=bo, bh=bh, interpret=interpret,
              policy=pol, block_plan=block_plan)
    has_ends = ends is not None and any(e is not None for e in ends)
    if has_ends:
        # Ends replicate — pure-DP dispatch only (core.fno guards TP off).
        assert not tp_on and act == "gelu", (tp_on, act)
        ends_specs = jax.tree_util.tree_map(
            lambda a: P(*(None,) * a.ndim), ends)
        fn = compat_shard_map(
            lambda xl, wrl, wil, wbl, bl, el: fno_block_ends_nd(
                xl, wrl, wil, wbl, bl, modes, lift=el[0], proj=el[1],
                path="pallas", **kw),
            mesh, in_specs=(xspec, wspec, wspec, wbspec, bspec, ends_specs),
            out_specs=out_spec)
        return fn(x, wr, wi, wb, bias, ends)

    def local(xl, wrl, wil, wbl, bl):
        if not tp_on:
            return fno_block_nd(xl, wrl, wil, wbl, bl, modes,
                                path="pallas", act=act, **kw)
        # Partial pre-activations emit at the ACCUMULATOR dtype (f32 under
        # the bf16 policy) so the cross-shard contraction — reduction +
        # bias + activation — stays f32 end-to-end; the single down-cast
        # to the compute dtype is the return (same contract as the
        # in-kernel epilogue it replaces).
        # The kernel's bias slot gets a full-width zero (under the
        # scattered layout bl is this shard's 1/tp slice — the real bias
        # applies only after the reduction, on the scattered chunk).
        z = fno_block_nd(xl, wrl, wil, wbl,
                         jnp.zeros((wrl.shape[0],), bl.dtype), modes,
                         path="pallas", act="linear",
                         out_dtype=pol.accum_dtype, **kw)
        if scatter:
            # bl arrives pre-sliced to this shard's chunk (bspec).
            z = (ring_scatter_sum(z, model_axis, tp) if tp_overlap
                 else scatter_sum(z, model_axis))
        else:
            z = jax.lax.psum(z, model_axis)
        z = z + bl.astype(z.dtype).reshape((1, -1) + (1,) * r)
        if act == "gelu":
            z = jax.nn.gelu(z, approximate=True)
        return z.astype(jnp.dtype(pol.compute_dtype))

    fn = compat_shard_map(
        local, mesh,
        in_specs=(xspec, wspec, wspec, wbspec, bspec),
        out_specs=out_spec)
    return fn(x, wr, wi, wb, bias)
