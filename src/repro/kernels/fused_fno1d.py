"""Compatibility wrappers for the 1D fused FNO kernels.

The kernel bodies moved to the rank-generic engine
(``repro.kernels.engine``), which emits the same grid/accumulator layout
for every spatial rank — see engine.py's module docstring for the layout
notes that used to live here. These wrappers pin rank 1 and preserve the
original positional-operand signatures.

For the WHOLE FNO block — gelu(spectral(x) + 1×1 bypass + bias) in one
pallas_call, end-to-end differentiable — use the block API instead:
``engine.fused_fno_block_call`` (raw kernel) or ``ops.fno_block_nd``
(padded, custom_vjp, rank-generic).
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.kernels import engine


def fused_fno1d_call(x: jax.Array, wr: jax.Array, wi: jax.Array,
                     cr: jax.Array, ci: jax.Array, er: jax.Array,
                     ei: jax.Array, bb: int, bo: int, bh: int,
                     interpret: bool = False) -> jax.Array:
    """x: [B,H,N] real; w: [O,H] or [O,H,K]; c: [N,K]; e: [K,N] -> y [B,O,N].

    All of B,O,H must divide by (bb,bo,bh); K,N are whole blocks (ops.py
    pads everything to (8,128)-aligned shapes).
    """
    return engine.fused_fnond_call(x, wr, wi, cr, ci, er, ei,
                                   bb=bb, bo=bo, bh=bh, interpret=interpret)


def fused_fno1d_wgrad_call(x: jax.Array, g: jax.Array, cr: jax.Array,
                           ci: jax.Array, etr: jax.Array, eti: jax.Array,
                           bb: int, bo: int, bh: int, per_mode: bool,
                           interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,H,N] primal; g: [B,O,N] cotangent; c,et: [N,K].

    Returns (dwr, dwi): [O,H] shared, or [K,O,H] per-mode (caller
    transposes back to [O,H,K]).
    """
    return engine.fused_fnond_wgrad_call(x, g, cr, ci, etr, eti,
                                         bb=bb, bo=bo, bh=bh,
                                         per_mode=per_mode,
                                         interpret=interpret)
