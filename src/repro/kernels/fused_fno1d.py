"""Fused truncated-rDFT → CGEMM → padded-irDFT Pallas kernel (1D FNO layer).

This is the paper's core contribution (§4.3) mapped to TPU:

  * grid = (batch tiles, out-channel tiles, hidden tiles) with the HIDDEN
    axis innermost — the FFT "pencils" are selected along the GEMM k-loop
    direction exactly as in paper Fig. 6(c);
  * per program, the truncated forward DFT of the x-slice is computed
    straight into VMEM registers and consumed as the CGEMM A-tile — the
    shared-memory forwarding of Fig. 7 with no HBM round trip;
  * the iDFT runs as the CGEMM epilogue on the VMEM accumulator — Fig. 8;
  * truncation/zero-padding/pruning are implicit in the DFT operand shapes.

Layout note (the TPU replacement for warp swizzling): every contraction is
arranged so no operand needs an in-kernel transpose —

    x[bb,bh,N] · Cr[N,K]                  -> A[bb,bh,K]
    A[bb,bh,K] ·(bh) W[bo,bh]             -> acc[bb,K,bo]   (shared W)
    acc[bb,K,bo] ·(K) Er[K,N]             -> y[bb,bo,N]

i.e. the accumulator is laid out [batch, modes, out] so that both the CGEMM
accumulation and the iDFT epilogue are plain dot_generals over the minor
dims. For per-mode weights W[bo,bh,K] the accumulator is [K,bb,bo] with K as
a batched dot dimension.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compiler_params

_F32 = jnp.float32


def _dot(a, b, dims):
    return jax.lax.dot_general(a, b, (dims, ((), ())),
                               preferred_element_type=_F32)


def _fused_kernel_shared(x_ref, wr_ref, wi_ref, cr_ref, ci_ref, er_ref,
                         ei_ref, y_ref, accr, acci):
    """Shared-weight (paper CGEMM) variant. Block shapes:
    x[bb,bh,N] w[bo,bh] c[N,K] e[K,N] y[bb,bo,N] acc[bb,K,bo]."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        accr[...] = jnp.zeros_like(accr)
        acci[...] = jnp.zeros_like(acci)

    x = x_ref[...]
    # Truncated forward rDFT along N — the "FFT writing its A-tile to smem".
    ar = _dot(x, cr_ref[...], (((2,), (0,))))  # [bb,bh,K]
    ai = _dot(x, ci_ref[...], (((2,), (0,))))
    # CGEMM over hidden (the k-loop MAC): contract bh -> acc[bb,K,bo].
    wr, wi = wr_ref[...], wi_ref[...]
    accr[...] += _dot(ar, wr, (((1,), (1,)))) - _dot(ai, wi, (((1,), (1,))))
    acci[...] += _dot(ar, wi, (((1,), (1,)))) + _dot(ai, wr, (((1,), (1,))))

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        # Padded irDFT epilogue: contract K -> y[bb,bo,N].
        yr = _dot(accr[...], er_ref[...], (((1,), (0,))))
        yi = _dot(acci[...], ei_ref[...], (((1,), (0,))))
        y_ref[...] = (yr - yi).astype(y_ref.dtype)


def _fused_kernel_permode(x_ref, wr_ref, wi_ref, cr_ref, ci_ref, er_ref,
                          ei_ref, y_ref, accr, acci):
    """Per-mode-weight (classic FNO) variant. w[bo,bh,K]; acc[K,bb,bo]."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        accr[...] = jnp.zeros_like(accr)
        acci[...] = jnp.zeros_like(acci)

    x = x_ref[...]
    ar = _dot(x, cr_ref[...], (((2,), (0,))))  # [bb,bh,K]
    ai = _dot(x, ci_ref[...], (((2,), (0,))))
    wr, wi = wr_ref[...], wi_ref[...]

    def bdot(a, w):  # batched over K: [bb,bh,K]x[bo,bh,K] -> [K,bb,bo]
        return jax.lax.dot_general(
            a, w, (((1,), (1,)), ((2,), (2,))), preferred_element_type=_F32)

    accr[...] += bdot(ar, wr) - bdot(ai, wi)
    acci[...] += bdot(ar, wi) + bdot(ai, wr)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        yr = _dot(accr[...], er_ref[...], (((0,), (0,))))  # [bb,bo,N]
        yi = _dot(acci[...], ei_ref[...], (((0,), (0,))))
        y_ref[...] = (yr - yi).astype(y_ref.dtype)


# ---------------------------------------------------------------------------
# Fused weight-gradient kernel (backward pass of the spectral layer).
#
# With A = DFT(x) ([B,H,K] complex) and G = g @ Eᵀ (the output cotangent
# pushed into the spectral domain, [B,O,K] complex), the weight cotangent is
#
#     dW[o,h(,m)] = conj( Σ_b G[b,o,m]·A[b,h,m] )     (Σ_m too when shared)
#
# — a fused rank-reduction: both DFTs are computed straight into VMEM and
# consumed by the reduction without an HBM round trip, mirroring the forward
# kernel's Fig. 7 forwarding. Grid = (out tiles, hidden tiles, batch tiles)
# with BATCH innermost as the accumulation loop.
# ---------------------------------------------------------------------------
def _wgrad_kernel(x_ref, g_ref, cr_ref, ci_ref, etr_ref, eti_ref,
                  dwr_ref, dwi_ref, accr, acci):
    """Blocks: x[bb,bh,N] g[bb,bo,N] c[N,K] et[N,K];
    dw[bo,bh] shared / dw[K,bo,bh] per-mode (caller transposes; acc matches
    dw)."""
    per_mode = dwr_ref.ndim == 3

    @pl.when(pl.program_id(2) == 0)
    def _init():
        accr[...] = jnp.zeros_like(accr)
        acci[...] = jnp.zeros_like(acci)

    x, g = x_ref[...], g_ref[...]
    ar = _dot(x, cr_ref[...], (((2,), (0,))))   # A = DFT(x): [bb,bh,K]
    ai = _dot(x, ci_ref[...], (((2,), (0,))))
    gr = _dot(g, etr_ref[...], (((2,), (0,))))  # G = g@Eᵀ: [bb,bo,K]
    gi = _dot(g, eti_ref[...], (((2,), (0,))))

    if per_mode:
        def rdot(p, q):  # batched over K: [bb,bo,K]x[bb,bh,K] -> [K,bo,bh]
            return jax.lax.dot_general(p, q, (((0,), (0,)), ((2,), (2,))),
                                       preferred_element_type=_F32)
    else:
        def rdot(p, q):  # contract (b, K): [bb,bo,K]x[bb,bh,K] -> [bo,bh]
            return jax.lax.dot_general(p, q, (((0, 2), (0, 2)), ((), ())),
                                       preferred_element_type=_F32)

    accr[...] += rdot(gr, ar) - rdot(gi, ai)
    acci[...] += rdot(gr, ai) + rdot(gi, ar)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        # dW = conj(acc): real part as-is, imaginary part negated.
        dwr_ref[...] = accr[...].astype(dwr_ref.dtype)
        dwi_ref[...] = (-acci[...]).astype(dwi_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bb", "bo", "bh", "per_mode", "interpret"))
def fused_fno1d_wgrad_call(x: jax.Array, g: jax.Array, cr: jax.Array,
                           ci: jax.Array, etr: jax.Array, eti: jax.Array,
                           bb: int, bo: int, bh: int, per_mode: bool,
                           interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,H,N] primal; g: [B,O,N] cotangent; c,et: [N,K].

    Returns (dwr, dwi): [O,H] shared, or [K,O,H] per-mode (caller transposes
    back to [O,H,K]). All of B,O,H must divide by (bb,bo,bh); K,N whole
    blocks (ops.py pads).
    """
    b, h, n = x.shape
    o = g.shape[1]
    k = cr.shape[1]
    grid = (o // bo, h // bh, b // bb)

    x_spec = pl.BlockSpec((bb, bh, n), lambda i, j, kb: (kb, j, 0))
    g_spec = pl.BlockSpec((bb, bo, n), lambda i, j, kb: (kb, i, 0))
    m_spec = pl.BlockSpec((n, k), lambda i, j, kb: (0, 0))
    if per_mode:
        dw_spec = pl.BlockSpec((k, bo, bh), lambda i, j, kb: (0, i, j))
        dw_shape = (k, o, h)
        acc_shape = (k, bo, bh)
    else:
        dw_spec = pl.BlockSpec((bo, bh), lambda i, j, kb: (i, j))
        dw_shape = (o, h)
        acc_shape = (bo, bh)
    out_sd = jax.ShapeDtypeStruct(dw_shape, x.dtype)

    return pl.pallas_call(
        _wgrad_kernel,
        grid=grid,
        in_specs=[x_spec, g_spec, m_spec, m_spec, m_spec, m_spec],
        out_specs=[dw_spec, dw_spec],
        out_shape=[out_sd, out_sd],
        scratch_shapes=[pltpu.VMEM(acc_shape, _F32),
                        pltpu.VMEM(acc_shape, _F32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, g, cr, ci, etr, eti)


@functools.partial(
    jax.jit, static_argnames=("bb", "bo", "bh", "interpret"))
def fused_fno1d_call(x: jax.Array, wr: jax.Array, wi: jax.Array,
                     cr: jax.Array, ci: jax.Array, er: jax.Array,
                     ei: jax.Array, bb: int, bo: int, bh: int,
                     interpret: bool = False) -> jax.Array:
    """x: [B,H,N] real; w: [O,H] or [O,H,K]; c: [N,K]; e: [K,N] -> y [B,O,N].

    All of B,O,H must divide by (bb,bo,bh); K,N are whole blocks (ops.py
    pads everything to (8,128)-aligned shapes).
    """
    b, h, n = x.shape
    o = wr.shape[0]
    k = cr.shape[1]
    per_mode = wr.ndim == 3
    grid = (b // bb, o // bo, h // bh)

    x_spec = pl.BlockSpec((bb, bh, n), lambda i, j, kk: (i, kk, 0))
    if per_mode:
        w_spec = pl.BlockSpec((bo, bh, k), lambda i, j, kk: (j, kk, 0))
        acc_shape = (k, bb, bo)
        kernel = _fused_kernel_permode
    else:
        w_spec = pl.BlockSpec((bo, bh), lambda i, j, kk: (j, kk))
        acc_shape = (bb, k, bo)
        kernel = _fused_kernel_shared
    c_spec = pl.BlockSpec((n, k), lambda i, j, kk: (0, 0))
    e_spec = pl.BlockSpec((k, n), lambda i, j, kk: (0, 0))
    y_spec = pl.BlockSpec((bb, bo, n), lambda i, j, kk: (i, j, 0))

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[x_spec, w_spec, w_spec, c_spec, c_spec, e_spec, e_spec],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((b, o, n), x.dtype),
        scratch_shapes=[pltpu.VMEM(acc_shape, _F32),
                        pltpu.VMEM(acc_shape, _F32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wr, wi, cr, ci, er, ei)
