"""Pallas TPU kernels: truncated rDFT / padded irDFT as MXU matmuls.

These are the standalone "FFT with built-in truncation / zero-padding"
kernels (paper §3.3): truncation = the DFT operand simply has `modes`
columns; zero-padding = the iDFT operand has `modes` rows. No separate copy
kernels exist anywhere. Pruning = the rows of the full DFT matrix that are
never materialized (docs/DESIGN.md §3.2).

Grid: 1-D over row-tiles of the flattened batch. The DFT matrices are
broadcast operands resident in VMEM for every program.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import _compiler_params

_F32 = jnp.float32

# 1-D grid over independent row-tiles — no cross-program accumulation.
_SEMANTICS = ("parallel",)


def _rdft_kernel(x_ref, cr_ref, ci_ref, xr_ref, xi_ref):
    x = x_ref[...]
    xr_ref[...] = jax.lax.dot(x, cr_ref[...], preferred_element_type=_F32
                              ).astype(xr_ref.dtype)
    xi_ref[...] = jax.lax.dot(x, ci_ref[...], preferred_element_type=_F32
                              ).astype(xi_ref.dtype)


def _irdft_kernel(xr_ref, xi_ref, er_ref, ei_ref, y_ref):
    yr = jax.lax.dot(xr_ref[...], er_ref[...], preferred_element_type=_F32)
    yi = jax.lax.dot(xi_ref[...], ei_ref[...], preferred_element_type=_F32)
    y_ref[...] = (yr - yi).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _rdft_call(x2d: jax.Array, cr: jax.Array, ci: jax.Array,
               block_rows: int, interpret: bool) -> Tuple[jax.Array, jax.Array]:
    m, n = x2d.shape
    k = cr.shape[1]
    grid = (m // block_rows,)
    spec_x = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    spec_m = pl.BlockSpec((n, k), lambda i: (0, 0))
    spec_o = pl.BlockSpec((block_rows, k), lambda i: (i, 0))
    out_sd = jax.ShapeDtypeStruct((m, k), x2d.dtype)
    return pl.pallas_call(
        _rdft_kernel,
        grid=grid,
        in_specs=[spec_x, spec_m, spec_m],
        out_specs=[spec_o, spec_o],
        out_shape=[out_sd, out_sd],
        compiler_params=_compiler_params(dimension_semantics=_SEMANTICS),
        interpret=interpret,
    )(x2d, cr, ci)


def _cdft_kernel(xr_ref, xi_ref, fr_ref, fi_ref, or_ref, oi_ref):
    """Complex-to-complex truncated DFT / padded iDFT (the operand decides
    which): 4 real MXU matmuls."""
    xr, xi = xr_ref[...], xi_ref[...]
    fr, fi = fr_ref[...], fi_ref[...]
    dot = lambda a, b: jax.lax.dot(a, b, preferred_element_type=_F32)
    or_ref[...] = (dot(xr, fr) - dot(xi, fi)).astype(or_ref.dtype)
    oi_ref[...] = (dot(xr, fi) + dot(xi, fr)).astype(oi_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _cdft_call(xr2d: jax.Array, xi2d: jax.Array, fr: jax.Array,
               fi: jax.Array, block_rows: int,
               interpret: bool) -> Tuple[jax.Array, jax.Array]:
    m, n = xr2d.shape
    k = fr.shape[1]
    grid = (m // block_rows,)
    spec_x = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    spec_m = pl.BlockSpec((n, k), lambda i: (0, 0))
    spec_o = pl.BlockSpec((block_rows, k), lambda i: (i, 0))
    out_sd = jax.ShapeDtypeStruct((m, k), xr2d.dtype)
    return pl.pallas_call(
        _cdft_kernel,
        grid=grid,
        in_specs=[spec_x, spec_x, spec_m, spec_m],
        out_specs=[spec_o, spec_o],
        out_shape=[out_sd, out_sd],
        compiler_params=_compiler_params(dimension_semantics=_SEMANTICS),
        interpret=interpret,
    )(xr2d, xi2d, fr, fi)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _irdft_call(xr2d: jax.Array, xi2d: jax.Array, er: jax.Array, ei: jax.Array,
                block_rows: int, interpret: bool) -> jax.Array:
    m, k = xr2d.shape
    n = er.shape[1]
    grid = (m // block_rows,)
    spec_x = pl.BlockSpec((block_rows, k), lambda i: (i, 0))
    spec_m = pl.BlockSpec((k, n), lambda i: (0, 0))
    spec_o = pl.BlockSpec((block_rows, n), lambda i: (i, 0))
    return pl.pallas_call(
        _irdft_kernel,
        grid=grid,
        in_specs=[spec_x, spec_x, spec_m, spec_m],
        out_specs=spec_o,
        out_shape=jax.ShapeDtypeStruct((m, n), xr2d.dtype),
        compiler_params=_compiler_params(dimension_semantics=_SEMANTICS),
        interpret=interpret,
    )(xr2d, xi2d, er, ei)
