"""Pallas TPU kernels for the fused FFT→CGEMM→iFFT pipeline.

Version-compat policy (ROADMAP.md §Compat): the kernels support JAX 0.4.x
and ≥0.5. API renames are absorbed here, in one place, so the kernel
modules themselves stay version-agnostic.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax ≥0.5 renamed TPUCompilerParams -> CompilerParams. Resolve once at
# import time; both accept the same kwargs we use (dimension_semantics).
_COMPILER_PARAMS_CLS = getattr(pltpu, "TPUCompilerParams", None) or getattr(
    pltpu, "CompilerParams")


def _compiler_params(**kwargs):
    """Build pltpu compiler params on any supported JAX version."""
    return _COMPILER_PARAMS_CLS(**kwargs)
