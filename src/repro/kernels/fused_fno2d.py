"""Compatibility wrappers for the 2D fused FNO kernels.

The kernel bodies moved to the rank-generic engine
(``repro.kernels.engine``). These wrappers pin rank 2 and preserve the
original positional-operand signatures:

* ``fused_fno2d_call`` — paper-faithful partial fusion middle (§4.3,
  Fig. 6): [truncated cDFT along X → CGEMM → padded icDFT along X] on the
  complex stage-1 output (engine ``fused_fnond_core_call``).
* ``fused_fno2d_full_call`` — beyond-paper full fusion: the entire layer
  [rDFT_Y → cDFT_X → CGEMM → icDFT_X → irDFT_Y] in one kernel.
* ``fused_fno2d_wgrad_call`` — fused rank-reduction weight gradient.

For the WHOLE FNO block — gelu(spectral(x) + 1×1 bypass + bias) in one
pallas_call, end-to-end differentiable — use the block API instead:
``engine.fused_fno_block_call`` (raw kernel) or ``ops.fno_block_nd``
(padded, custom_vjp, rank-generic).
"""
from __future__ import annotations

from typing import Tuple

import jax

from repro.kernels import engine


def fused_fno2d_call(zr: jax.Array, zi: jax.Array, wr: jax.Array,
                     wi: jax.Array, fr: jax.Array, fi: jax.Array,
                     gr: jax.Array, gi: jax.Array, bb: int, bo: int, bh: int,
                     interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """z: [B,H,X,KY] complex pair (stage-1 output); w: [O,H] or
    [O,H,KX,KY]; f: [X,KX]; g: [KX,X]. Returns y pair — [B,KY,O,X] shared
    or [KY,B,O,X] per-mode (caller transposes)."""
    return engine.fused_fnond_core_call(zr, zi, wr, wi, fr, fi, gr, gi,
                                        bb=bb, bo=bo, bh=bh,
                                        interpret=interpret)


def fused_fno2d_full_call(x: jax.Array, wr: jax.Array, wi: jax.Array,
                          cr: jax.Array, ci: jax.Array, fr: jax.Array,
                          fi: jax.Array, gr: jax.Array, gi: jax.Array,
                          er: jax.Array, ei: jax.Array, bb: int, bo: int,
                          bh: int, interpret: bool = False) -> jax.Array:
    """Whole 2D FNO spectral layer in one kernel.

    x: [B,H,X,Y] real; w: [O,H] or [O,H,KX,KY]; c: [Y,KY]; f: [X,KX];
    g: [KX,X]; e: [KY,Y]. Returns y [B,O,X,Y] real.
    """
    return engine.fused_fnond_call(x, wr, wi, cr, ci, fr, fi, gr, gi,
                                   er, ei, bb=bb, bo=bo, bh=bh,
                                   interpret=interpret)


def fused_fno2d_wgrad_call(x: jax.Array, g: jax.Array, cr: jax.Array,
                           ci: jax.Array, fr: jax.Array, fi: jax.Array,
                           etr: jax.Array, eti: jax.Array, gtr: jax.Array,
                           gti: jax.Array, bb: int, bo: int, bh: int,
                           per_mode: bool, interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,H,X,Y] primal; g: [B,O,X,Y] cotangent; c,et: [Y,KY];
    f,gt: [X,KX]. Returns (dwr, dwi): [O,H] shared or [KY,KX,O,H] per-mode
    (caller transposes back to [O,H,KX,KY])."""
    return engine.fused_fnond_wgrad_call(x, g, cr, ci, fr, fi, etr, eti,
                                         gtr, gti, bb=bb, bo=bo, bh=bh,
                                         per_mode=per_mode,
                                         interpret=interpret)
