"""Fused 2D FNO-layer Pallas kernels.

Two variants:

* ``fused_fno2d_call`` — paper-faithful partial fusion (§4.3, Fig. 6): the
  stage-1 truncated rDFT along Y runs as a separate kernel (see dft.py); this
  kernel fuses [truncated cDFT along X → CGEMM over hidden → padded icDFT
  along X], operating on complex stage-1 output. Matches TurboFNO, which
  fuses only the FFT stage adjacent to the GEMM.

* ``fused_fno2d_full_call`` — BEYOND-paper full fusion: the entire layer
  [rDFT_Y → cDFT_X → CGEMM → icDFT_X → irDFT_Y] in one kernel. Possible on
  TPU because FNO's out-channel count fits a single lane tile (O ≤ 128), so
  fusing the producer rDFT into the k-loop incurs no re-reads. §Perf
  quantifies the extra HBM-traffic saving over the paper's scheme.

Accumulator layouts avoid all in-kernel transposes (see fused_fno1d.py).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import _compiler_params

_F32 = jnp.float32


def _dot(a, b, dims):
    return jax.lax.dot_general(a, b, (dims, ((), ())),
                               preferred_element_type=_F32)


# ---------------------------------------------------------------------------
# Paper-faithful partial fusion: cDFT_X -> CGEMM -> icDFT_X
# ---------------------------------------------------------------------------
def _fused2d_kernel(zr_ref, zi_ref, wr_ref, wi_ref, fr_ref, fi_ref,
                    gr_ref, gi_ref, yr_ref, yi_ref, accr, acci):
    """Blocks: z[bb,bh,X,KY], w[bo,bh], f[X,KX], g[KX,X],
    y[bb,KY,bo,X], acc[bb,KY,KX,bo]."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        accr[...] = jnp.zeros_like(accr)
        acci[...] = jnp.zeros_like(acci)

    zr, zi = zr_ref[...], zi_ref[...]
    fr, fi = fr_ref[...], fi_ref[...]
    # Truncated complex DFT along X: contract dim 2 -> [bb,bh,KY,KX].
    ar = _dot(zr, fr, ((2,), (0,))) - _dot(zi, fi, ((2,), (0,)))
    ai = _dot(zr, fi, ((2,), (0,))) + _dot(zi, fr, ((2,), (0,)))
    # CGEMM over hidden: contract bh -> acc[bb,KY,KX,bo].
    wr, wi = wr_ref[...], wi_ref[...]
    accr[...] += _dot(ar, wr, ((1,), (1,))) - _dot(ai, wi, ((1,), (1,)))
    acci[...] += _dot(ar, wi, ((1,), (1,))) + _dot(ai, wr, ((1,), (1,)))

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        # Padded icDFT along X: contract KX -> [bb,KY,bo,X].
        gr, gi = gr_ref[...], gi_ref[...]
        cr, ci = accr[...], acci[...]
        yr_ref[...] = (_dot(cr, gr, ((2,), (0,)))
                       - _dot(ci, gi, ((2,), (0,)))).astype(yr_ref.dtype)
        yi_ref[...] = (_dot(cr, gi, ((2,), (0,)))
                       + _dot(ci, gr, ((2,), (0,)))).astype(yi_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bo", "bh", "interpret"))
def fused_fno2d_call(zr: jax.Array, zi: jax.Array, wr: jax.Array,
                     wi: jax.Array, fr: jax.Array, fi: jax.Array,
                     gr: jax.Array, gi: jax.Array, bb: int, bo: int, bh: int,
                     interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """z: [B,H,X,KY] complex pair (stage-1 output); w: [O,H]; f: [X,KX];
    g: [KX,X]. Returns y pair [B,KY,O,X] (caller transposes)."""
    b, h, x, ky = zr.shape
    o = wr.shape[0]
    kx = fr.shape[1]
    grid = (b // bb, o // bo, h // bh)

    z_spec = pl.BlockSpec((bb, bh, x, ky), lambda i, j, kk: (i, kk, 0, 0))
    w_spec = pl.BlockSpec((bo, bh), lambda i, j, kk: (j, kk))
    f_spec = pl.BlockSpec((x, kx), lambda i, j, kk: (0, 0))
    g_spec = pl.BlockSpec((kx, x), lambda i, j, kk: (0, 0))
    y_spec = pl.BlockSpec((bb, ky, bo, x), lambda i, j, kk: (i, 0, j, 0))
    out_sd = jax.ShapeDtypeStruct((b, ky, o, x), zr.dtype)

    return pl.pallas_call(
        _fused2d_kernel,
        grid=grid,
        in_specs=[z_spec, z_spec, w_spec, w_spec, f_spec, f_spec,
                  g_spec, g_spec],
        out_specs=[y_spec, y_spec],
        out_shape=[out_sd, out_sd],
        scratch_shapes=[pltpu.VMEM((bb, ky, kx, bo), _F32),
                        pltpu.VMEM((bb, ky, kx, bo), _F32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(zr, zi, wr, wi, fr, fi, gr, gi)


# ---------------------------------------------------------------------------
# Fused 2D weight-gradient kernel (backward pass).
#
# With A = the truncated 2D spectrum of x (forward stages 1-2, [B,H,KY,KX])
# and Ĝ = the output cotangent pushed into the spectral domain through the
# transposed inverse transforms (g @ Eᵀ along Y, then @ G_invᵀ along X,
# [B,O,KY,KX]), the weight cotangent is
#
#   dW[o,h(,kx,ky)] = conj( Σ_b Ĝ[b,o,ky,kx]·A[b,h,ky,kx] )   (Σ_{ky,kx}
#                                                              when shared)
#
# Both spectra are computed in VMEM and consumed by the rank-reduction with
# no HBM round trip. Grid = (out, hidden, batch) with batch innermost.
# ---------------------------------------------------------------------------
def _wgrad2d_kernel(x_ref, g_ref, cr_ref, ci_ref, fr_ref, fi_ref, etr_ref,
                    eti_ref, gtr_ref, gti_ref, dwr_ref, dwi_ref, accr, acci):
    """Blocks: x[bb,bh,X,Y] g[bb,bo,X,Y] c,et[Y,KY] f,gt[X,KX];
    dw[bo,bh] shared / dw[KY,KX,bo,bh] per-mode (acc matches dw)."""
    per_mode = dwr_ref.ndim == 4

    @pl.when(pl.program_id(2) == 0)
    def _init():
        accr[...] = jnp.zeros_like(accr)
        acci[...] = jnp.zeros_like(acci)

    xv, gv = x_ref[...], g_ref[...]
    # A: rDFT along Y then cDFT along X -> [bb,bh,KY,KX].
    zr = _dot(xv, cr_ref[...], ((3,), (0,)))
    zi = _dot(xv, ci_ref[...], ((3,), (0,)))
    fr, fi = fr_ref[...], fi_ref[...]
    ar = _dot(zr, fr, ((2,), (0,))) - _dot(zi, fi, ((2,), (0,)))
    ai = _dot(zr, fi, ((2,), (0,))) + _dot(zi, fr, ((2,), (0,)))
    # Ĝ: transposed-irDFT along Y then transposed-icDFT along X
    # -> [bb,bo,KY,KX].
    tr = _dot(gv, etr_ref[...], ((3,), (0,)))
    ti = _dot(gv, eti_ref[...], ((3,), (0,)))
    gtr, gti = gtr_ref[...], gti_ref[...]
    hr = _dot(tr, gtr, ((2,), (0,))) - _dot(ti, gti, ((2,), (0,)))
    hi = _dot(tr, gti, ((2,), (0,))) + _dot(ti, gtr, ((2,), (0,)))

    if per_mode:
        def rdot(p, q):  # contract b, batch (KY,KX) -> [KY,KX,bo,bh]
            return jax.lax.dot_general(
                p, q, (((0,), (0,)), ((2, 3), (2, 3))),
                preferred_element_type=_F32)
    else:
        def rdot(p, q):  # contract (b,KY,KX) -> [bo,bh]
            return jax.lax.dot_general(
                p, q, (((0, 2, 3), (0, 2, 3)), ((), ())),
                preferred_element_type=_F32)

    accr[...] += rdot(hr, ar) - rdot(hi, ai)
    acci[...] += rdot(hr, ai) + rdot(hi, ar)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        dwr_ref[...] = accr[...].astype(dwr_ref.dtype)
        dwi_ref[...] = (-acci[...]).astype(dwi_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("bb", "bo", "bh", "per_mode", "interpret"))
def fused_fno2d_wgrad_call(x: jax.Array, g: jax.Array, cr: jax.Array,
                           ci: jax.Array, fr: jax.Array, fi: jax.Array,
                           etr: jax.Array, eti: jax.Array, gtr: jax.Array,
                           gti: jax.Array, bb: int, bo: int, bh: int,
                           per_mode: bool, interpret: bool = False
                           ) -> Tuple[jax.Array, jax.Array]:
    """x: [B,H,X,Y] primal; g: [B,O,X,Y] cotangent; c,et: [Y,KY];
    f,gt: [X,KX]. Returns (dwr, dwi): [O,H] shared or [KY,KX,O,H] per-mode
    (caller transposes back to [O,H,KX,KY])."""
    b, h, nx, ny = x.shape
    o = g.shape[1]
    ky = cr.shape[1]
    kx = fr.shape[1]
    grid = (o // bo, h // bh, b // bb)

    x_spec = pl.BlockSpec((bb, bh, nx, ny), lambda i, j, kb: (kb, j, 0, 0))
    g_spec = pl.BlockSpec((bb, bo, nx, ny), lambda i, j, kb: (kb, i, 0, 0))
    mat = lambda r, c_: pl.BlockSpec((r, c_), lambda i, j, kb: (0, 0))
    if per_mode:
        dw_spec = pl.BlockSpec((ky, kx, bo, bh),
                               lambda i, j, kb: (0, 0, i, j))
        dw_shape = (ky, kx, o, h)
        acc_shape = (ky, kx, bo, bh)
    else:
        dw_spec = pl.BlockSpec((bo, bh), lambda i, j, kb: (i, j))
        dw_shape = (o, h)
        acc_shape = (bo, bh)
    out_sd = jax.ShapeDtypeStruct(dw_shape, x.dtype)

    return pl.pallas_call(
        _wgrad2d_kernel,
        grid=grid,
        in_specs=[x_spec, g_spec, mat(ny, ky), mat(ny, ky), mat(nx, kx),
                  mat(nx, kx), mat(ny, ky), mat(ny, ky), mat(nx, kx),
                  mat(nx, kx)],
        out_specs=[dw_spec, dw_spec],
        out_shape=[out_sd, out_sd],
        scratch_shapes=[pltpu.VMEM(acc_shape, _F32),
                        pltpu.VMEM(acc_shape, _F32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, g, cr, ci, fr, fi, etr, eti, gtr, gti)


# ---------------------------------------------------------------------------
# Beyond-paper full fusion: rDFT_Y -> cDFT_X -> CGEMM -> icDFT_X -> irDFT_Y
# ---------------------------------------------------------------------------
def _fused2d_full_kernel(x_ref, wr_ref, wi_ref, cr_ref, ci_ref, fr_ref,
                         fi_ref, gr_ref, gi_ref, er_ref, ei_ref, y_ref,
                         accr, acci):
    """Blocks: x[bb,bh,X,Y], w[bo,bh] (or [bo,bh,KX,KY]), c[Y,KY], f[X,KX],
    g[KX,X], e[KY,Y], y[bb,bo,X,Y], acc[bb,KY,KX,bo] ([KY,KX,bb,bo] permode).
    """
    per_mode = wr_ref.ndim == 4

    @pl.when(pl.program_id(2) == 0)
    def _init():
        accr[...] = jnp.zeros_like(accr)
        acci[...] = jnp.zeros_like(acci)

    xv = x_ref[...]
    # Stage 1: truncated rDFT along Y (real input) -> [bb,bh,X,KY].
    zr = _dot(xv, cr_ref[...], ((3,), (0,)))
    zi = _dot(xv, ci_ref[...], ((3,), (0,)))
    # Stage 2: truncated cDFT along X -> [bb,bh,KY,KX].
    fr, fi = fr_ref[...], fi_ref[...]
    ar = _dot(zr, fr, ((2,), (0,))) - _dot(zi, fi, ((2,), (0,)))
    ai = _dot(zr, fi, ((2,), (0,))) + _dot(zi, fr, ((2,), (0,)))
    wr, wi = wr_ref[...], wi_ref[...]
    if per_mode:
        # batched over (KX,KY): [bb,bh,KY,KX]x[bo,bh,KX,KY] -> [KY,KX,bb,bo]
        def bdot(a, w):
            return jax.lax.dot_general(
                a, w, (((1,), (1,)), ((2, 3), (3, 2))),
                preferred_element_type=_F32)
    else:
        def bdot(a, w):  # contract bh -> [bb,KY,KX,bo]
            return _dot(a, w, ((1,), (1,)))
    accr[...] += bdot(ar, wr) - bdot(ai, wi)
    acci[...] += bdot(ar, wi) + bdot(ai, wr)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _epilogue():
        gr, gi = gr_ref[...], gi_ref[...]
        cr_, ci_ = accr[...], acci[...]
        kx_axis = 1 if per_mode else 2
        # Padded icDFT along X: -> [bb,KY,bo,X] (or [KY,bb,bo,X] permode).
        tr = (_dot(cr_, gr, ((kx_axis,), (0,)))
              - _dot(ci_, gi, ((kx_axis,), (0,))))
        ti = (_dot(cr_, gi, ((kx_axis,), (0,)))
              + _dot(ci_, gr, ((kx_axis,), (0,))))
        # Padded irDFT along Y (real output): contract KY -> [bb,bo,X,Y].
        ky_axis = 0 if per_mode else 1
        y = (_dot(tr, er_ref[...], ((ky_axis,), (0,)))
             - _dot(ti, ei_ref[...], ((ky_axis,), (0,))))
        if per_mode:  # [bb,bo,X,Y] already (KY was dim0, bb dim1 -> dims ok)
            pass
        y_ref[...] = y.astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bb", "bo", "bh", "interpret"))
def fused_fno2d_full_call(x: jax.Array, wr: jax.Array, wi: jax.Array,
                          cr: jax.Array, ci: jax.Array, fr: jax.Array,
                          fi: jax.Array, gr: jax.Array, gi: jax.Array,
                          er: jax.Array, ei: jax.Array, bb: int, bo: int,
                          bh: int, interpret: bool = False) -> jax.Array:
    """Whole 2D FNO spectral layer in one kernel.

    x: [B,H,X,Y] real; w: [O,H] or [O,H,KX,KY]; c: [Y,KY]; f: [X,KX];
    g: [KX,X]; e: [KY,Y]. Returns y [B,O,X,Y] real.
    """
    b, h, nx, ny = x.shape
    o = wr.shape[0]
    ky = cr.shape[1]
    kx = fr.shape[1]
    per_mode = wr.ndim == 4
    grid = (b // bb, o // bo, h // bh)

    x_spec = pl.BlockSpec((bb, bh, nx, ny), lambda i, j, kk: (i, kk, 0, 0))
    if per_mode:
        w_spec = pl.BlockSpec((bo, bh, kx, ky), lambda i, j, kk: (j, kk, 0, 0))
        acc_shape = (ky, kx, bb, bo)
    else:
        w_spec = pl.BlockSpec((bo, bh), lambda i, j, kk: (j, kk))
        acc_shape = (bb, ky, kx, bo)
    mat = lambda r, c_: pl.BlockSpec((r, c_), lambda i, j, kk: (0, 0))
    y_spec = pl.BlockSpec((bb, bo, nx, ny), lambda i, j, kk: (i, j, 0, 0))

    return pl.pallas_call(
        _fused2d_full_kernel,
        grid=grid,
        in_specs=[x_spec, w_spec, w_spec, mat(ny, ky), mat(ny, ky),
                  mat(nx, kx), mat(nx, kx), mat(kx, nx), mat(kx, nx),
                  mat(ky, ny), mat(ky, ny)],
        out_specs=y_spec,
        out_shape=jax.ShapeDtypeStruct((b, o, nx, ny), x.dtype),
        scratch_shapes=[pltpu.VMEM(acc_shape, _F32),
                        pltpu.VMEM(acc_shape, _F32)],
        compiler_params=_compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, wr, wi, cr, ci, fr, fi, gr, gi, er, ei)
