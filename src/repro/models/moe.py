"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Dispatch never materializes the [T, E, C] one-hot (which at train_4k scale
would be tens of GB): token→slot assignment is computed by a stable argsort
over expert ids + per-expert prefix offsets, then a scatter into the
[E, C, d] expert buffer. FLOPs therefore scale with *active* capacity, which
keeps the dry-run cost_analysis honest for MoE archs (MODEL_FLOPS uses
6·N_active·D).

Expert-parallel sharding: the [E, C, d] buffer is sharded over the model
axis when E divides it (arctic 128e); otherwise experts are replicated and
TP shards the expert FFN dim (mixtral 8e on a 16-way axis) — see
``distributed.sharding.param_specs``.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_activation
from repro.models.layers import dense_init


def moe_init(key, cfg: ModelConfig, dtype):
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    ks = jax.random.split(key, 4)
    scale = (1.0 / d) ** 0.5
    experts = {
        "wi": scale * jax.random.normal(ks[0], (e, d, f), dtype),
        "wo": scale * jax.random.normal(ks[1], (e, f, d), dtype) / f ** 0.5 * d ** 0.5,
    }
    if cfg.mlp in ("swiglu", "geglu"):
        experts["wg"] = scale * jax.random.normal(ks[2], (e, d, f), dtype)
    return {"router": dense_init(ks[3], d, e, dtype), "experts": experts}


def _expert_ffn(experts: Dict, buf: jax.Array, kind: str) -> jax.Array:
    """buf: [B, E, C, d] -> [B, E, C, d]; batched over experts."""
    h = jnp.einsum("becd,edf->becf", buf, experts["wi"])
    if kind == "swiglu":
        h = jax.nn.silu(jnp.einsum("becd,edf->becf", buf, experts["wg"])) * h
    elif kind == "geglu":
        h = jax.nn.gelu(jnp.einsum("becd,edf->becf", buf, experts["wg"])) * h
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    return jnp.einsum("becf,efd->becd", h, experts["wo"])


def apply_moe(params, x: jax.Array, cfg: ModelConfig
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, d] -> (y [B, S, d], aux load-balance loss).

    Dispatch is PER BATCH ROW: sort/offset/scatter indices never cross the
    batch dim, so under pjit the whole dispatch stays sharded over the data
    axis with no all-gathers (a global-token dispatch buffer replicated
    per chip cost 21 GB/chip for mixtral prefill in the dry-run). Capacity
    is per-row: C = ceil(S·k/E·cf).
    """
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    sk = s * k

    logits = (x @ params["router"]["w"]).astype(jnp.float32)  # [B, S, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_logit, top_idx = jax.lax.top_k(logits, k)  # [B, S, k]
    gates = jax.nn.softmax(top_logit, axis=-1).astype(x.dtype)

    # load-balance aux (Switch): E * mean(load_frac * prob_frac)
    load = jnp.zeros((e,), jnp.float32).at[top_idx.reshape(-1)].add(
        1.0) / (b * sk)
    importance = probs.mean((0, 1))
    aux = e * jnp.sum(load * importance)

    # ---- sort-based per-row dispatch -----------------------------------
    cap = int(math.ceil(sk / e * cfg.capacity_factor))
    cap = max(8, (cap + 7) // 8 * 8)
    rows = jnp.arange(b)[:, None]
    fe = top_idx.reshape(b, sk)
    order = jnp.argsort(fe, axis=-1, stable=True)  # [B, sk]
    fe_s = jnp.take_along_axis(fe, order, axis=-1)
    tok_s = order // k  # source token within the row
    counts = jnp.zeros((b, e), jnp.int32).at[rows, fe_s].add(1)
    starts = jnp.cumsum(counts, axis=-1) - counts  # exclusive prefix
    slot = jnp.arange(sk)[None, :] - jnp.take_along_axis(starts, fe_s,
                                                         axis=-1)
    keep = slot < cap
    slot_c = jnp.where(keep, slot, 0)

    rows_b = jnp.broadcast_to(rows, (b, sk))
    x_sorted = jnp.take_along_axis(x, tok_s[..., None], axis=1)  # [B,sk,d]
    buf = jnp.zeros((b, e, cap, d), x.dtype)
    buf = buf.at[rows_b, fe_s, slot_c].add(
        jnp.where(keep[..., None], x_sorted, 0), mode="drop")
    buf = shard_activation(buf, "experts")

    out_buf = _expert_ffn(params["experts"], buf, cfg.mlp)
    out_buf = shard_activation(out_buf, "experts")

    y_s = out_buf[rows_b, fe_s, slot_c] * keep[..., None].astype(x.dtype)
    # unsort back to [B, sk, d], weight by gates, sum over the k choices
    y_flat = jnp.zeros((b, sk, d), x.dtype).at[rows_b, order].set(y_s)
    y = (y_flat.reshape(b, s, k, d) * gates[..., None]).sum(axis=2)
    return y, aux
