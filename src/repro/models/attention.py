"""GQA attention: blockwise online-softmax for train/prefill, dense single-
token attention over the KV cache for decode.

Memory posture (no Pallas here — the paper's kernels are the FNO ones):
  * train/prefill: outer scan over query blocks, inner scan over KV blocks
    with running (max, denom, acc) — peak score tensor is
    [B, q_block, Hkv, G, kv_block] regardless of sequence length.
  * sliding-window: per query block only the [window + q_block] KV slice is
    gathered (dynamic_slice), so FLOPs/bytes scale O(S·W) not O(S²).
  * full causal attention computes masked upper-triangle blocks (the usual
    XLA-level 2× FLOP overcount vs. an ideal triangular kernel); recorded in
    EXPERIMENTS.md §Roofline as part of MODEL_FLOPS/HLO_FLOPs.
  * decode: one dense [B, H, 1, S] score row over the cache — linear in S.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_activation
from repro.models.layers import dense, dense_init

_NEG = -1e30


def attn_init(key, cfg: ModelConfig, dtype):
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.d_attn, dtype, cfg.qkv_bias),
        "wk": dense_init(ks[1], d, cfg.d_kv, dtype, cfg.qkv_bias),
        "wv": dense_init(ks[2], d, cfg.d_kv, dtype, cfg.qkv_bias),
        "wo": dense_init(ks[3], cfg.d_attn, d, dtype, False),
    }


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _score_penalty(pos_q, pos_k, causal: bool, window: int, kv_len=None):
    """[Sq, Sk] additive f32 penalty (0 valid / -1e30 masked).

    Added to scores rather than applied via jnp.where(mask, s, NEG): the
    additive form is constant w.r.t. activations, so the backward pass
    saves nothing — a where() would checkpoint a boolean tensor that XLA
    hoists out of the layer scan broadcast to full score shape (gigabytes
    at 4k context; observed on the 96-layer dry-run cell)."""
    m = jnp.ones(pos_q.shape[-1:] + pos_k.shape[-1:], jnp.bool_)
    pq, pk = pos_q[:, None], pos_k[None, :]
    if causal:
        m &= pk <= pq
    if window > 0:
        m &= pk > pq - window
    if kv_len is not None:
        m &= pk < kv_len
    return jnp.where(m, 0.0, _NEG).astype(jnp.float32)


def _attend_block(qb, ks, vs, pos_q, pos_k, causal, window, softcap,
                  kv_len=None, kv_block: int = 512):
    """Online-softmax attention of one query block against a KV slice.

    qb: [B,Bq,Hkv,G,D]; ks/vs: [B,Sk,Hkv,D]. Returns [B,Bq,Hkv,G,D].
    """
    b, bq, hkv, g, dh = qb.shape
    sk = ks.shape[1]
    scale = dh ** -0.5
    nkv = sk // kv_block
    ks_b = ks.reshape(b, nkv, kv_block, hkv, dh)
    vs_b = vs.reshape(b, nkv, kv_block, hkv, dh)
    pk_b = pos_k.reshape(nkv, kv_block)
    qf = qb.astype(jnp.float32) * scale

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, pk = xs
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kb.astype(jnp.float32))
        if softcap > 0:
            s = jnp.tanh(s / softcap) * softcap
        s = s + _score_penalty(pos_q, pk, causal, window, kv_len)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                vb.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, g, bq), _NEG, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, bq, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (ks_b.swapaxes(0, 1), vs_b.swapaxes(0, 1), pk_b))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(qb.dtype)  # [B,Bq,Hkv,G,D]


def _rup(v, m):
    return (v + m - 1) // m * m


def multihead_attention(q, k, v, *, causal: bool, window: int = 0,
                        softcap: float = 0.0, q_offset: int = 0,
                        q_block: int = 256, kv_block: int = 512):
    """q: [B,Sq,Hq,D]; k/v: [B,Sk,Hkv,D] -> [B,Sq,Hq,D].

    Positions are absolute: query i has position q_offset + i; key j has
    position j. window>0 restricts to the last `window` keys (SWA).
    """
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    q_block = min(q_block, sq)
    while sq % q_block:
        q_block //= 2
    kv_block = min(kv_block, sk)
    while sk % kv_block:
        kv_block //= 2
    nq = sq // q_block
    qg = q.reshape(b, nq, q_block, hkv, g, dh)
    pos_q_all = q_offset + jnp.arange(sq).reshape(nq, q_block)
    pos_k = jnp.arange(sk)

    use_window_slice = window > 0 and sk > _rup(window + q_block, kv_block)

    if not use_window_slice:
        def qbody(_, xs):
            qb, pq = xs
            o = _attend_block(qb, k, v, pq, pos_k, causal, window, softcap,
                              kv_block=kv_block)
            return None, o
        _, out = jax.lax.scan(qbody, None, (qg.swapaxes(0, 1), pos_q_all))
    else:
        wlen = _rup(window + q_block, kv_block)

        def qbody(_, xs):
            qb, pq = xs
            # last key this block can see is pq_max; slice [start, start+wlen)
            start = jnp.clip(pq[-1] + 1 - wlen, 0, sk - wlen)
            ks = jax.lax.dynamic_slice_in_dim(k, start, wlen, axis=1)
            vs = jax.lax.dynamic_slice_in_dim(v, start, wlen, axis=1)
            pk = start + jnp.arange(wlen)
            o = _attend_block(qb, ks, vs, pq, pk, causal, window, softcap,
                              kv_block=kv_block)
            return None, o
        _, out = jax.lax.scan(qbody, None, (qg.swapaxes(0, 1), pos_q_all))

    return out.swapaxes(0, 1).reshape(b, sq, hq, dh)


def decode_attention_pos(q, k_cache, v_cache, pos_k, q_pos, *,
                         window: int = 0, softcap: float = 0.0):
    """Single-token attention over a (possibly ring) cache.

    q: [B,1,Hq,D]; caches: [B,Sc,Hkv,D]; pos_k: [Sc] absolute token position
    of each cache slot (< 0 = empty); q_pos: the query's absolute position.
    Dense over Sc — O(cache size) per step.
    """
    b, _, hq, dh = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    qf = q.reshape(b, hkv, g, dh).astype(jnp.float32) * dh ** -0.5
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    valid = (pos_k >= 0) & (pos_k <= q_pos)
    if window > 0:
        valid &= pos_k > q_pos - window
    s = s + jnp.where(valid, 0.0, _NEG).astype(jnp.float32)[None, None, None]
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return o.reshape(b, 1, hq, dh).astype(q.dtype)
