"""Unified LM-family transformer: one implementation, ten architectures.

Heterogeneous layer patterns (gemma3's 5 local : 1 global, hymba's three
global layers) are handled by grouping consecutive same-kind layers into
SEGMENTS: within a segment the attention window is static, so jax.lax.scan
runs over the segment's stacked params and sliding-window layers get the
O(S·W) dynamic-slice attention path (models/attention.py).

KV caches are per-segment: sliding-window segments use RING buffers of size
~window (so a 500k-context mixtral decode reads 4k keys/layer, not 500k),
full-attention segments use full-length buffers. SSM layers carry O(1)
recurrent state. Cache pytree:

    {"segments": [ {"k","v": [nl,B,Sc,Hkv,D]} | {"conv","ssm": ...} | both ],
     "len": int32 }
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import current_context, shard_activation
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, apply_rope, dense,
                                 dense_init, mlp_init, norm_init)


# ---------------------------------------------------------------------------
# segments
# ---------------------------------------------------------------------------
def layer_flags(cfg: ModelConfig) -> List[bool]:
    """Per-layer is_global flag (True = full attention, no window)."""
    n = cfg.num_layers
    if not cfg.has_attention or cfg.attention in ("full", "bidirectional"):
        return [True] * n
    if cfg.attention == "local_global":
        per = cfg.local_per_global + 1
        return [(i % per) == cfg.local_per_global for i in range(n)]
    # swa: windowed everywhere except explicit global layers
    return [i in cfg.global_layers for i in range(n)]


def segments(cfg: ModelConfig) -> List[Tuple[int, int, bool]]:
    """Contiguous (start, end, is_global) runs of layers."""
    flags = layer_flags(cfg)
    segs, s = [], 0
    for i in range(1, cfg.num_layers + 1):
        if i == cfg.num_layers or flags[i] != flags[s]:
            segs.append((s, i, flags[s]))
            s = i
    return segs


def _tree_slice(tree, s, e):
    return jax.tree_util.tree_map(lambda a: a[s:e], tree)


def _rup(v, m):
    return (v + m - 1) // m * m


def ring_size(cfg: ModelConfig, is_global: bool, max_len: int) -> int:
    if is_global or cfg.window_size <= 0:
        return max_len
    return min(max_len, _rup(cfg.window_size + 1, 128))


def _kv_rep() -> int:
    """KV-head replication factor for TP (1 outside a sharding context)."""
    ctx = current_context()
    return ctx.kv_repeat_factor if ctx else 1


def effective_kv_heads(cfg: ModelConfig) -> int:
    return cfg.num_kv_heads * _kv_rep()


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def init_lm(key: jax.Array, cfg: ModelConfig, dtype=None) -> Dict[str, Any]:
    cfg.validate()
    dtype = dtype or jnp.dtype(cfg.dtype)
    kemb, klay, khead = jax.random.split(key, 3)

    def init_layer(k):
        ks = jax.random.split(k, 4)
        lp: Dict[str, Any] = {"ln1": norm_init(cfg.d_model, cfg.norm, dtype)}
        if cfg.has_attention:
            lp["attn"] = attn.attn_init(ks[0], cfg, dtype)
        if cfg.has_ssm:
            lp["ssm"] = ssm_mod.ssm_init(ks[1], cfg, dtype)
        if cfg.d_ff > 0:
            if cfg.num_experts:
                lp["moe"] = moe_mod.moe_init(ks[2], cfg, dtype)
                if cfg.dense_residual:
                    lp["mlp"] = mlp_init(ks[3], cfg, dtype)
            else:
                lp["mlp"] = mlp_init(ks[3], cfg, dtype)
            lp["ln2"] = norm_init(cfg.d_model, cfg.norm, dtype)
        return lp

    params = {
        "embed": (1.0 / cfg.d_model ** 0.5) * jax.random.normal(
            kemb, (cfg.vocab_size, cfg.d_model), dtype),
        "layers": jax.vmap(init_layer)(
            jax.random.split(klay, cfg.num_layers)),
        "final_norm": norm_init(cfg.d_model, cfg.norm, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(khead, cfg.d_model, cfg.vocab_size,
                                       dtype)
    return params


# ---------------------------------------------------------------------------
# layer bodies
# ---------------------------------------------------------------------------
def _attn_sublayer(lp, h, cfg: ModelConfig, positions, *, window: int,
                   q_block: int, kv_block: int):
    b, s, _ = h.shape
    q = dense(lp["wq"], h).reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = dense(lp["wk"], h).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    v = dense(lp["wv"], h).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    r = _kv_rep()
    if r > 1:  # replicate KV heads so each TP shard owns whole heads
        k = jnp.repeat(k, r, axis=2)
        v = jnp.repeat(v, r, axis=2)
    q = shard_activation(q, "heads")
    k = shard_activation(k, "kv")
    v = shard_activation(v, "kv")
    o = attn.multihead_attention(
        q, k, v, causal=cfg.is_decoder, window=window,
        softcap=cfg.logit_softcap, q_block=q_block, kv_block=kv_block)
    out = dense(lp["wo"], o.reshape(b, s, -1))
    return out, (k, v)


def _mlp_sublayer(lp, x, cfg: ModelConfig):
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff <= 0:
        return jnp.zeros_like(x), aux
    h2 = apply_norm(lp["ln2"], x, cfg.norm)
    if cfg.num_experts:
        y, aux = moe_mod.apply_moe(lp["moe"], h2, cfg)
        if cfg.dense_residual:
            y = y + apply_mlp(lp["mlp"], h2, cfg.mlp)
    else:
        y = apply_mlp(lp["mlp"], h2, cfg.mlp)
    return y, aux


def _layer_fwd(lp, x, cfg: ModelConfig, positions, *, window: int,
               q_block: int = 256, kv_block: int = 512,
               want_state: bool = False):
    """Full-sequence layer. Returns (x', aux, (k, v), ssm_state)."""
    h = apply_norm(lp["ln1"], x, cfg.norm)
    parts, kv, ssm_state = [], None, None
    if cfg.has_attention:
        o, kv = _attn_sublayer(lp["attn"], h, cfg, positions, window=window,
                               q_block=q_block, kv_block=kv_block)
        parts.append(o)
    if cfg.has_ssm:
        if want_state:
            o, ssm_state = ssm_mod.ssd_forward(lp["ssm"], h, cfg,
                                               return_state=True)
        else:
            o = ssm_mod.ssd_forward(lp["ssm"], h, cfg)
        parts.append(o)
    mix = sum(parts) / len(parts) if cfg.hybrid_parallel else sum(parts)
    x = x + mix
    y, aux = _mlp_sublayer(lp, x, cfg)
    x = x + y
    x = shard_activation(x, "embed")
    return x, aux, kv, ssm_state


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------
def embed_inputs(params, cfg: ModelConfig, tokens=None, inputs_embeds=None,
                 prefix_embeds=None) -> jax.Array:
    if inputs_embeds is not None:
        x = inputs_embeds
    else:
        x = params["embed"][tokens]
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(x.dtype), x], axis=1)
    return shard_activation(x, "embed")


def lm_logits(params, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"].T
    else:
        logits = dense(params["lm_head"], x)
    return shard_activation(logits, "logits")


# ---------------------------------------------------------------------------
# train forward
# ---------------------------------------------------------------------------
def forward(params, cfg: ModelConfig, tokens=None, inputs_embeds=None,
            prefix_embeds=None, q_block: int = 256, kv_block: int = 512,
            remat: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits [B,S,V], moe aux loss).

    remat=True checkpoints each layer (recompute in backward) — the
    standard memory/FLOP trade for the big assigned archs at train_4k.
    """
    x = embed_inputs(params, cfg, tokens, inputs_embeds, prefix_embeds)
    positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
    aux_total = jnp.zeros((), jnp.float32)

    for (s, e, is_global) in segments(cfg):
        window = 0 if is_global else cfg.window_size
        sub = _tree_slice(params["layers"], s, e)

        def one_layer(lp, xx, window=window):
            return _layer_fwd(lp, xx, cfg, positions, window=window,
                              q_block=q_block, kv_block=kv_block)[:2]

        if remat:
            one_layer = jax.checkpoint(
                one_layer,
                policy=jax.checkpoint_policies.nothing_saveable)

        if e - s == 1:
            lp = jax.tree_util.tree_map(lambda a: a[0], sub)
            x, aux = one_layer(lp, x)
            aux_total += aux
        else:
            def body(carry, lp):
                xx, acc = carry
                xx, aux = one_layer(lp, xx)
                return (xx, acc + aux), None
            (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), sub)

    return lm_logits(params, cfg, x), aux_total


def lm_loss(params, cfg: ModelConfig, batch: Dict[str, jax.Array],
            aux_coef: float = 0.01, remat: bool = False) -> jax.Array:
    """batch: tokens [B,S], labels [B,S] (-1 = ignore), optional
    inputs_embeds / prefix_embeds."""
    logits, aux = forward(
        params, cfg, batch.get("tokens"), batch.get("inputs_embeds"),
        batch.get("prefix_embeds"), remat=remat)
    labels = batch["labels"]
    npad = logits.shape[1] - labels.shape[1]
    if npad:  # prefix embeds: no loss on prefix positions
        logits = logits[:, npad:]
    mask = labels >= 0
    labels_c = jnp.maximum(labels, 0)
    # logsumexp - gather form: never materializes a full-vocab f32
    # log_softmax tensor (at 150k vocab that array dominates HBM)
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    tgt = jnp.take_along_axis(logits, labels_c[..., None], axis=-1)[..., 0]
    nll = lse - tgt.astype(jnp.float32)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1)
    return loss + aux_coef * aux


# ---------------------------------------------------------------------------
# prefill
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=None
               ) -> Dict[str, Any]:
    """Zero cache sized for `max_len` total positions."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    segs = []
    for (s, e, is_global) in segments(cfg):
        nl = e - s
        seg: Dict[str, Any] = {}
        if cfg.has_attention:
            sc = ring_size(cfg, is_global, max_len)
            kv_shape = (nl, batch, sc, effective_kv_heads(cfg), cfg.head_dim)
            seg["k"] = jnp.zeros(kv_shape, dtype)
            seg["v"] = jnp.zeros(kv_shape, dtype)
        if cfg.has_ssm:
            seg["conv"] = jnp.zeros(
                (nl, batch, cfg.ssm_conv_width - 1, cfg.d_inner), dtype)
            seg["ssm"] = jnp.zeros(
                (nl, batch, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                jnp.float32)
        segs.append(seg)
    return {"segments": segs, "len": jnp.zeros((), jnp.int32)}


def _to_ring(k: jax.Array, sc: int) -> jax.Array:
    """[B,S,...] full keys -> ring buffer [B,Sc,...] (token p at slot p%Sc)."""
    s = k.shape[1]
    if s <= sc:
        pad = [(0, 0)] * k.ndim
        pad[1] = (0, sc - s)
        return jnp.pad(k, pad)
    return jnp.roll(k[:, -sc:], s % sc, axis=1)


def prefill(params, cfg: ModelConfig, tokens=None, inputs_embeds=None,
            prefix_embeds=None, max_len: Optional[int] = None,
            q_block: int = 256, kv_block: int = 512
            ) -> Tuple[jax.Array, Dict[str, Any]]:
    """Returns (logits for the LAST position [B,V], populated cache)."""
    x = embed_inputs(params, cfg, tokens, inputs_embeds, prefix_embeds)
    b, s = x.shape[:2]
    max_len = max_len or s
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    segs_out = []

    for (st, en, is_global) in segments(cfg):
        window = 0 if is_global else cfg.window_size
        sub = _tree_slice(params["layers"], st, en)

        def body(xx, lp):
            xx, _, kv, ssm_state = _layer_fwd(
                lp, xx, cfg, positions, window=window, q_block=q_block,
                kv_block=kv_block, want_state=True)
            outs = {}
            if kv is not None:
                outs["k"], outs["v"] = kv
            if ssm_state is not None:
                outs["conv"], outs["ssm"] = ssm_state
            return xx, outs

        x, outs = jax.lax.scan(body, x, sub)
        seg: Dict[str, Any] = {}
        if "k" in outs:
            sc = ring_size(cfg, is_global, max_len)
            seg["k"] = jax.vmap(lambda kk: _to_ring(kk, sc))(outs["k"])
            seg["v"] = jax.vmap(lambda vv: _to_ring(vv, sc))(outs["v"])
        if "ssm" in outs:
            seg["conv"] = outs["conv"]
            seg["ssm"] = outs["ssm"]
        segs_out.append(seg)

    logits = lm_logits(params, cfg, x[:, -1:])
    return logits[:, 0], {"segments": segs_out,
                          "len": jnp.asarray(s, jnp.int32)}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def _ring_positions(sc: int, cur_len) -> jax.Array:
    """Absolute token position held by each ring slot AFTER writing the
    token at position cur_len into slot cur_len % sc. Empty slots < 0."""
    idx = jnp.arange(sc)
    p = cur_len - (cur_len - idx) % sc
    return jnp.where(p <= cur_len, p, p - sc)


def decode_step(params, cfg: ModelConfig, cache: Dict[str, Any],
                token: Optional[jax.Array] = None,
                token_embeds: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, Dict[str, Any]]:
    """One decode step. token: [B] int32 (or token_embeds [B,1,D]).
    Returns (logits [B,V], updated cache).

    The per-segment layer loop is a fori_loop whose CARRY holds the
    stacked cache arrays, updated in place by one dynamic-update-slice per
    layer — a lax.scan with the cache as xs/ys double-buffers it (2x KV
    memory on every decode cell in the dry-run)."""
    cur = cache["len"]  # new token's position
    if token_embeds is not None:
        x = token_embeds
    else:
        x = params["embed"][token][:, None]
    x = shard_activation(x, "embed")
    b = x.shape[0]
    positions = jnp.broadcast_to(cur, (b, 1))
    new_segs = []

    for seg_i, (st, en, is_global) in enumerate(segments(cfg)):
        window = 0 if is_global else cfg.window_size
        sub = _tree_slice(params["layers"], st, en)
        seg_cache = dict(cache["segments"][seg_i])

        def body(i, carry):
            xx, sc_ = carry
            sc_ = dict(sc_)
            lp = jax.tree_util.tree_map(
                lambda a: jax.lax.dynamic_index_in_dim(a, i, 0, False), sub)
            h = apply_norm(lp["ln1"], xx, cfg.norm)
            parts = []
            if cfg.has_attention:
                ap = lp["attn"]
                q = dense(ap["wq"], h).reshape(b, 1, cfg.num_heads,
                                               cfg.head_dim)
                k = dense(ap["wk"], h).reshape(b, 1, cfg.num_kv_heads,
                                               cfg.head_dim)
                v = dense(ap["wv"], h).reshape(b, 1, cfg.num_kv_heads,
                                               cfg.head_dim)
                q = apply_rope(q, positions, cfg)
                k = apply_rope(k, positions, cfg)
                r = _kv_rep()
                if r > 1:
                    k = jnp.repeat(k, r, axis=2)
                    v = jnp.repeat(v, r, axis=2)
                q = shard_activation(q, "heads")
                scap = sc_["k"].shape[2]
                slot = cur % scap
                zero = jnp.zeros((), jnp.int32)
                # in-place single-slot write into the stacked cache
                sc_["k"] = jax.lax.dynamic_update_slice(
                    sc_["k"], k.astype(sc_["k"].dtype)[None],
                    (i, zero, slot, zero, zero))
                sc_["v"] = jax.lax.dynamic_update_slice(
                    sc_["v"], v.astype(sc_["v"].dtype)[None],
                    (i, zero, slot, zero, zero))
                k_cache = jax.lax.dynamic_index_in_dim(sc_["k"], i, 0, False)
                v_cache = jax.lax.dynamic_index_in_dim(sc_["v"], i, 0, False)
                pos_k = _ring_positions(scap, cur)
                o = attn.decode_attention_pos(
                    q, k_cache, v_cache, pos_k, cur, window=window,
                    softcap=cfg.logit_softcap)
                parts.append(dense(ap["wo"], o.reshape(b, 1, -1)))
            if cfg.has_ssm:
                conv_i = jax.lax.dynamic_index_in_dim(sc_["conv"], i, 0,
                                                      False)
                ssm_i = jax.lax.dynamic_index_in_dim(sc_["ssm"], i, 0, False)
                o, (conv_new, ssm_new) = ssm_mod.ssd_decode_step(
                    lp["ssm"], h, (conv_i, ssm_i), cfg)
                parts.append(o)
                sc_["conv"] = jax.lax.dynamic_update_index_in_dim(
                    sc_["conv"], conv_new.astype(sc_["conv"].dtype), i, 0)
                sc_["ssm"] = jax.lax.dynamic_update_index_in_dim(
                    sc_["ssm"], ssm_new.astype(sc_["ssm"].dtype), i, 0)
            mix = (sum(parts) / len(parts) if cfg.hybrid_parallel
                   else sum(parts))
            xx = xx + mix
            y, _ = _mlp_sublayer(lp, xx, cfg)
            return (xx + y, sc_)

        x, seg_cache = jax.lax.fori_loop(0, en - st, body, (x, seg_cache))
        new_segs.append(seg_cache)

    logits = lm_logits(params, cfg, x)
    return logits[:, 0], {"segments": new_segs, "len": cur + 1}
