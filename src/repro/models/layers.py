"""Shared building blocks for the LM model zoo: norms, MLPs, RoPE, dense."""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_activation


def dense_init(key, din: int, dout: int, dtype, bias: bool = False):
    scale = (1.0 / din) ** 0.5
    p = {"w": scale * jax.random.normal(key, (din, dout), dtype)}
    if bias:
        p["b"] = jnp.zeros((dout,), dtype)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


# -- norms -------------------------------------------------------------------
def norm_init(d: int, kind: str, dtype):
    p = {"scale": jnp.ones((d,), dtype)}
    if kind == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p, x, kind: str, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rmsnorm":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# -- MLP ---------------------------------------------------------------------
def mlp_init(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp in ("swiglu", "geglu"):
        return {"wi": dense_init(ks[0], d, f, dtype),
                "wg": dense_init(ks[1], d, f, dtype),
                "wo": dense_init(ks[2], f, d, dtype)}
    return {"wi": dense_init(ks[0], d, f, dtype),
            "wo": dense_init(ks[2], f, d, dtype)}


def apply_mlp(p, x, kind: str):
    h = dense(p["wi"], x)
    if kind == "swiglu":
        h = jax.nn.silu(dense(p["wg"], x)) * h
    elif kind == "geglu":
        h = jax.nn.gelu(dense(p["wg"], x)) * h
    elif kind == "gelu":
        h = jax.nn.gelu(h)
    elif kind == "relu2":
        h = jnp.square(jax.nn.relu(h))
    else:
        raise ValueError(kind)
    h = shard_activation(h, "ffn")
    return dense(p["wo"], h)


# -- RoPE --------------------------------------------------------------------
def rope_frequencies(head_dim: int, fraction: float, base: float
                     ) -> jnp.ndarray:
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * fraction)
    rot -= rot % 2
    return 1.0 / (base ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jax.Array, positions: jax.Array, cfg: ModelConfig
               ) -> jax.Array:
    """x: [B, S, H, D]; positions: [B, S] (absolute). Rotates the first
    `rope_fraction` of D pairwise (partial/2d RoPE keeps the tail as-is)."""
    if cfg.rope_style == "none":
        return x
    inv = rope_frequencies(cfg.head_dim, cfg.rope_fraction, cfg.rope_base)
    rot = 2 * inv.shape[0]
    ang = positions[..., None].astype(jnp.float32) * inv  # [B,S,rot/2]
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xr = x[..., :rot].astype(jnp.float32)
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    y = jnp.stack([y1, y2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([y.astype(x.dtype), x[..., rot:]], axis=-1)
