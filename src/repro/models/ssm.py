"""Mamba2 / SSD (state-space duality) layer — chunked matmul formulation.

Forward uses the SSD block decomposition (Dao & Gu 2024): intra-chunk
"attention-like" term + inter-chunk state recurrence (a lax.scan over
chunks), so all heavy compute is MXU-friendly einsums. Decode keeps an O(1)
recurrent state per layer: (conv window, SSM state [H, N, P]).

Simplifications vs. the reference CUDA implementation (docs/DESIGN.md §5):
ngroups = 1 (B/C shared across heads, matching the configs' param counts);
the short causal conv + SiLU applies to the x branch only.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard_activation
from repro.models.layers import apply_norm, dense_init, norm_init

_MIN_DT = 1e-4


def ssm_init(key, cfg: ModelConfig, dtype):
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 7)
    p = {
        "in_x": dense_init(ks[0], d, di, dtype),
        "in_z": dense_init(ks[1], d, di, dtype),
        "in_b": dense_init(ks[2], d, n, dtype),
        "in_c": dense_init(ks[3], d, n, dtype),
        "in_dt": dense_init(ks[4], d, h, dtype, bias=True),
        "conv_w": 0.1 * jax.random.normal(ks[5], (cfg.ssm_conv_width, di),
                                          dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(dtype),
        "d": jnp.ones((h,), dtype),
        "norm": norm_init(di, "rmsnorm", dtype),
        "out": dense_init(ks[6], di, d, dtype),
    }
    return p


def _causal_conv(x: jax.Array, w: jax.Array,
                 init_state: Optional[jax.Array] = None):
    """Depthwise causal conv along seq. x: [B,S,di]; w: [K,di].

    Returns (y [B,S,di], final window [B,K-1,di])."""
    kw = w.shape[0]
    if init_state is None:
        init_state = jnp.zeros((x.shape[0], kw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([init_state, x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(kw))
    return y, xp[:, -(kw - 1):] if kw > 1 else init_state


def _proj_inputs(p, x, cfg: ModelConfig, conv_state=None):
    xb = x @ p["in_x"]["w"]
    z = x @ p["in_z"]["w"]
    b_ = (x @ p["in_b"]["w"]).astype(jnp.float32)
    c_ = (x @ p["in_c"]["w"]).astype(jnp.float32)
    dt = jax.nn.softplus(
        (x @ p["in_dt"]["w"]).astype(jnp.float32) + p["in_dt"]["b"]) + _MIN_DT
    xb, conv_out = _causal_conv(xb, p["conv_w"], conv_state)
    xb = jax.nn.silu(xb)
    xb = shard_activation(xb, "ssm_inner")
    return xb, z, b_, c_, dt, conv_out


def ssd_forward(p, x: jax.Array, cfg: ModelConfig,
                return_state: bool = False):
    """x: [B, S, d] -> y [B, S, d] (and final (conv, ssm) states)."""
    b, s, _ = x.shape
    hh, pp, nn = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    q = min(cfg.ssm_chunk, s)
    while s % q:
        q //= 2
    nc = s // q

    xb, z, b_, c_, dt, conv_fin = _proj_inputs(p, x, cfg)
    xh = xb.reshape(b, nc, q, hh, pp).astype(jnp.float32)
    bch = b_.reshape(b, nc, q, nn)
    cch = c_.reshape(b, nc, q, nn)
    dtc = dt.reshape(b, nc, q, hh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # [H]
    da = dtc * a  # [B,nc,Q,H]
    cum = jnp.cumsum(da, axis=2)  # inclusive within chunk
    xdt = xh * dtc[..., None]

    # intra-chunk: Y[i] += C_i·B_j · exp(cum_i - cum_j) · xdt_j  (j <= i)
    gb = jnp.einsum("bcin,bcjn->bcij", cch, bch)  # [B,nc,Q,Q]
    li = cum[:, :, :, None, :]  # i index
    lj = cum[:, :, None, :, :]  # j index
    tri = jnp.tril(jnp.ones((q, q), bool))[None, None, :, :, None]
    # mask BEFORE exp: exp of the masked (j>i) entries would overflow and
    # poison gradients (inf·0 = NaN in the backward pass)
    m = jnp.exp(jnp.where(tri, li - lj, -jnp.inf))
    y_intra = jnp.einsum("bcij,bcijh,bcjhp->bcihp", gb, m, xdt)

    # chunk-final local states: S_c = Σ_j exp(cum_last - cum_j) B_j ⊗ xdt_j
    dec_out = jnp.exp(cum[:, :, -1:, :] - cum)  # [B,nc,Q,H]
    s_loc = jnp.einsum("bcjh,bcjn,bcjhp->bchnp", dec_out, bch, xdt)

    # inter-chunk recurrence over chunks
    dec_chunk = jnp.exp(cum[:, :, -1, :])  # [B,nc,H]

    def body(hprev, xs):
        dc, sl = xs  # dc [B,H], sl [B,H,N,P]
        return dc[..., None, None] * hprev + sl, hprev

    h0 = jnp.zeros((b, hh, nn, pp), jnp.float32)
    h_fin, h_before = jax.lax.scan(
        body, h0, (dec_chunk.swapaxes(0, 1), s_loc.swapaxes(0, 1)))
    h_before = h_before.swapaxes(0, 1)  # [B,nc,H,N,P]

    y_inter = jnp.einsum("bcin,bcih,bchnp->bcihp", cch, jnp.exp(cum),
                         h_before)
    y = y_intra + y_inter + p["d"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(b, s, -1)
    y = apply_norm(p["norm"], (y * jax.nn.silu(z.astype(jnp.float32))
                               ).astype(x.dtype), "rmsnorm")
    out = y @ p["out"]["w"]
    if return_state:
        return out, (conv_fin, h_fin.astype(jnp.float32))
    return out


def ssd_decode_step(p, x: jax.Array, state: Tuple[jax.Array, jax.Array],
                    cfg: ModelConfig):
    """One-token recurrent step. x: [B, 1, d]; state = (conv [B,K-1,di],
    h [B,H,N,P]). Returns (y [B,1,d], new state)."""
    conv_state, h = state
    hh, pp = cfg.ssm_heads, cfg.ssm_head_dim
    xb, z, b_, c_, dt, conv_new = _proj_inputs(p, x, cfg, conv_state)
    xh = xb.reshape(x.shape[0], hh, pp).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, 0] * a)  # [B,H]
    upd = jnp.einsum("bh,bn,bhp->bhnp", dt[:, 0], b_[:, 0], xh)
    h_new = da[..., None, None] * h + upd
    y = jnp.einsum("bn,bhnp->bhp", c_[:, 0], h_new)
    y = y + p["d"].astype(jnp.float32)[:, None] * xh
    y = y.reshape(x.shape[0], 1, -1)
    y = apply_norm(p["norm"], (y * jax.nn.silu(z.astype(jnp.float32))
                               ).astype(x.dtype), "rmsnorm")
    return y @ p["out"]["w"], (conv_new, h_new)
