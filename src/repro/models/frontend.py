"""Modality frontend STUBS (per assignment: the transformer backbone is the
deliverable; frontends provide precomputed embeddings).

* audio (hubert): ``input_specs()`` supplies frame embeddings [B, T, d] — in
  the real system these come from the conv waveform encoder.
* vision (internvl2): patch embeddings [B, P, d] prepended to the token
  sequence — in the real system these come from InternViT + the MLP
  projector.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeSpec


def frontend_inputs(cfg: ModelConfig, shape: ShapeSpec, dtype=jnp.bfloat16
                    ) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for the modality embeddings of one batch."""
    b, s = shape.global_batch, shape.seq_len
    if cfg.frontend == "audio":
        return {"inputs_embeds":
                jax.ShapeDtypeStruct((b, s, cfg.d_model), dtype)}
    if cfg.frontend == "vision":
        return {"prefix_embeds":
                jax.ShapeDtypeStruct((b, cfg.num_prefix_embeds, cfg.d_model),
                                     dtype)}
    return {}


def fake_frontend_arrays(cfg: ModelConfig, batch: int, seq: int, key,
                         dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Random embeddings for smoke tests / examples."""
    if cfg.frontend == "audio":
        return {"inputs_embeds":
                jax.random.normal(key, (batch, seq, cfg.d_model), dtype)}
    if cfg.frontend == "vision":
        return {"prefix_embeds": jax.random.normal(
            key, (batch, cfg.num_prefix_embeds, cfg.d_model), dtype)}
    return {}
