"""Render EXPERIMENTS.md tables from dry-run JSONL records.

    PYTHONPATH=src python -m repro.roofline.report /tmp/dryrun_single.jsonl
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List


def load(paths: List[str]) -> List[Dict]:
    recs = []
    for p in paths:
        with open(p) as f:
            for line in f:
                if line.strip():
                    recs.append(json.loads(line))
    return recs


def fmt_bytes(b: float) -> str:
    if b >= 2 ** 30:
        return f"{b / 2**30:.2f}G"
    if b >= 2 ** 20:
        return f"{b / 2**20:.1f}M"
    return f"{b / 2**10:.0f}K"


def roofline_table(recs: List[Dict]) -> str:
    rows = ["| arch | shape | mesh | compute ms | memory ms | coll ms | "
            "bottleneck | HBM GiB/chip | useful | MFU≤ | collectives |",
            "|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if r.get("status") == "skip":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"— | — | — | SKIP: {r['reason']} | | | | |")
            continue
        if r.get("status") != "ok":
            rows.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                        f"— | — | — | FAIL | | | | {r.get('error','')[:60]} |")
            continue
        det = ",".join(f"{k[:6]}:{fmt_bytes(v)}"
                       for k, v in sorted(r["coll_detail"].items()))
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{r['t_compute_ms']:.2f} | {r['t_memory_ms']:.2f} | "
            f"{r['t_collective_ms']:.2f} | **{r['bottleneck']}** | "
            f"{r['hbm_per_chip_gib']:.2f} | {r['useful_flop_ratio']:.3f} | "
            f"{r['mfu_bound']:.3f} | {det} |")
    return "\n".join(rows)


def summary(recs: List[Dict]) -> str:
    ok = [r for r in recs if r.get("status") == "ok"]
    sk = [r for r in recs if r.get("status") == "skip"]
    fail = [r for r in recs if r.get("status") == "fail"]
    lines = [f"{len(ok)} compiled, {len(sk)} skipped, {len(fail)} failed."]
    if ok:
        worst = sorted(ok, key=lambda r: r["mfu_bound"])[:3]
        lines.append("worst MFU-bound cells: " + ", ".join(
            f"{r['arch']}×{r['shape']} ({r['mfu_bound']:.3f})"
            for r in worst))
        collb = [r for r in ok if r["bottleneck"] == "collective"]
        lines.append(f"collective-bound cells: "
                     + (", ".join(f"{r['arch']}×{r['shape']}"
                                  for r in collb) or "none"))
        nofit = [r for r in ok if not r["fits_hbm"]]
        if nofit:
            lines.append("OVER HBM: " + ", ".join(
                f"{r['arch']}×{r['shape']} ({r['hbm_per_chip_gib']:.1f}GiB)"
                for r in nofit))
    return "\n".join(lines)


def main() -> None:
    recs = load(sys.argv[1:])
    print(summary(recs))
    print()
    print(roofline_table(recs))


if __name__ == "__main__":
    main()
