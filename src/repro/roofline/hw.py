"""TPU v5e hardware constants (per chip) for the roofline model."""

PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_LINK_BW = 50e9  # B/s per link
HBM_BYTES = 16 * 2 ** 30  # 16 GiB per chip
