"""Version-compat shims for XLA's compiled-executable introspection APIs.

One home for the ``compiled.cost_analysis()`` list-vs-dict normalization
(ROADMAP.md §JAX version compat): on jax 0.4.x it returns a list of dicts
(one per partitioned module), on newer releases a single dict. Every call
site goes through :func:`cost_analysis_dict` instead of normalizing
inline.
"""
from __future__ import annotations

from typing import Dict


def cost_analysis_dict(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as one flat dict on any supported JAX.

    jax 0.4.x returns ``[{...}]`` (list of per-module dicts; the entry
    module is first), ≥0.5 returns ``{...}``. An empty list (seen for
    trivially-empty modules) normalizes to ``{}`` so callers can
    ``.get(...)`` unconditionally.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)
