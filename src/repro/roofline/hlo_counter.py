"""Multiplicity-aware FLOP / byte / collective counter over compiled HLO.

XLA's ``compiled.cost_analysis()`` counts while-loop bodies ONCE (verified:
an 8-iteration lax.scan reports 8x fewer flops than its unrolled twin), so
for scan-over-layers models both FLOPs and in-loop collective bytes are
wildly understated. This module parses ``compiled.as_text()`` (post-SPMD,
per-device module) and walks the call graph with multiplicities:

  * while ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
    body and condition are multiplied by n;
  * fusion computations contribute FLOPs but not bytes (internal regs);
  * dots: 2 · prod(result dims) · prod(lhs contracting dims);
  * elementwise arithmetic: 1 flop per output element; reduce: per input
    element;
  * bytes: operands + result per top-level instruction (XLA convention);
  * collectives: result bytes per type, multiplicity-weighted.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2, "s32": 4,
    "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "power", "maximum", "minimum",
    "tanh", "exponential", "log", "negate", "abs", "sqrt", "rsqrt",
    "logistic", "sine", "cosine", "floor", "ceil", "round-nearest-afz",
    "round-nearest-even", "sign", "atan2", "remainder", "expm1", "log1p",
    "cbrt", "erf",
}

_COLLECTIVES = {
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute", "all-reduce-start", "all-gather-start",
    "collective-permute-start",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^=]*?\))|(?:[\w]+\[[^\]]*\](?:\{[^}]*\})?))\s+"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%([\w.\-]+)")
_BODY_RE = re.compile(r"body=%([\w.\-]+)")
_COND_RE = re.compile(r"condition=%([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_LHS_C_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")
_PARAM_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*"
    r"((?:\([^=]*?\))|(?:[\w]+\[[^\]]*\](?:\{[^}]*\})?))\s+parameter\(")


def shape_elems_bytes(shape_str: str) -> Tuple[int, int]:
    """(element count, bytes) of a (possibly tuple) shape string."""
    elems = 0
    nbytes = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        nbytes += n * _DTYPE_BYTES[dt]
    if elems == 0 and "[" not in shape_str:
        # scalar like "f32[]" handled above; bare scalar tokens:
        m = re.match(r"\(?(\w+)\b", shape_str)
        if m and m.group(1) in _DTYPE_BYTES:
            return 1, _DTYPE_BYTES[m.group(1)]
    return elems, nbytes


@dataclasses.dataclass
class Instr:
    name: str
    shape: str
    opcode: str
    rest: str  # operands + attrs tail


@dataclasses.dataclass
class Computation:
    name: str
    instrs: List[Instr]
    shapes: Dict[str, str]  # instr/param name -> shape string


_COMMENT_RE = re.compile(r"/\*.*?\*/")


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry_name = None
    for line in text.splitlines():
        # strip /*index=N*/ comments inside long tuple types: they contain
        # '=' and ')' characters that break the instruction grammar
        if "/*" in line:
            line = _COMMENT_RE.sub("", line)
        if not line.strip():
            continue
        if not line.startswith(" ") and ("->" in line) and line.rstrip(
                ).endswith("{"):
            m = _COMP_RE.match(line.strip())
            if m:
                cur = Computation(m.group(1), [], {})
                comps[cur.name] = cur
                if line.startswith("ENTRY"):
                    entry_name = cur.name
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _INSTR_RE.match(line)
        if m:
            name, shape, opcode, rest = m.groups()
            cur.instrs.append(Instr(name, shape, opcode, rest))
            cur.shapes[name] = shape
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


@dataclasses.dataclass
class Counts:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    unknown_trip_loops: int = 0

    def add(self, other: "Counts", mult: float = 1.0,
            count_bytes: bool = True) -> None:
        self.flops += other.flops * mult
        if count_bytes:
            self.bytes += other.bytes * mult
        for k, v in other.coll_bytes.items():
            self.coll_bytes[k] = self.coll_bytes.get(k, 0.0) + v * mult
        self.unknown_trip_loops += other.unknown_trip_loops


_ZERO_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "partition-id", "replica-id", "iota"}
# slicing ops touch only the slice, not the whole operand buffer
_SLICE_LIKE = {"dynamic-slice", "slice", "gather"}


def _instr_bytes(ins: Instr, comp: Computation, out_bytes: int) -> float:
    """HBM bytes touched by one top-level instruction.

    XLA-convention approximations: slicing ops read+write the slice;
    dynamic-update-slice reads+writes the update region (in-place buffer);
    scatter reads/writes the update region twice (read-modify-write);
    while/call/tuple plumbing is free (bodies counted separately);
    everything else reads its operands and writes its result.
    """
    if ins.opcode in _ZERO_BYTES:
        return 0.0
    if ins.opcode in _SLICE_LIKE:
        return 2.0 * out_bytes
    if ins.opcode in ("dynamic-update-slice", "scatter"):
        ops = _OPERAND_RE.findall(ins.rest.split(" metadata=")[0])
        upd_bytes = 0
        for opnd in ops[1:]:  # update operand(s); skip the big buffer
            _, b = shape_elems_bytes(comp.shapes.get(opnd, ""))
            upd_bytes += b
        return 2.0 * max(upd_bytes, 1)
    if ins.opcode in ("broadcast",):
        return float(out_bytes)
    ops = []
    for opnd in _OPERAND_RE.findall(ins.rest.split(" calls=")[0]
                                    .split(" metadata=")[0]):
        _, b = shape_elems_bytes(comp.shapes.get(opnd, ""))
        ops.append(b)
    if ins.opcode == "fusion" and "dynamic-update-slice" in ins.name:
        # in-place DUS fusion: the big buffer operand aliases the result;
        # only the update region moves
        return 2.0 * max(sum(ops) - max(ops, default=0), 1)
    return float(out_bytes + sum(ops))


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_elems, _ = shape_elems_bytes(instr.shape)
    ops = _OPERAND_RE.findall(instr.rest)
    k = 1.0
    m = _LHS_C_RE.search(instr.rest)
    if m and ops:
        lhs_shape = comp.shapes.get(ops[0], "")
        sm = _SHAPE_RE.search(lhs_shape)
        if sm:
            dims = [int(d) for d in sm.group(2).split(",") if d]
            for ci in m.group(1).split(","):
                if ci and int(ci) < len(dims):
                    k *= dims[int(ci)]
    return 2.0 * out_elems * k


def _analyze(comp: Computation, comps: Dict[str, Computation],
             memo: Dict[Tuple[str, bool], Counts],
             in_fusion: bool) -> Counts:
    key = (comp.name, in_fusion)
    if key in memo:
        return memo[key]
    c = Counts()
    for ins in comp.instrs:
        out_elems, out_bytes = shape_elems_bytes(ins.shape)
        # ---- bytes (only at non-fusion level) ----
        if not in_fusion:
            c.bytes += _instr_bytes(ins, comp, out_bytes)
        # ---- collectives ----
        if ins.opcode in _COLLECTIVES:
            base = ins.opcode.replace("-start", "")
            c.coll_bytes[base] = c.coll_bytes.get(base, 0.0) + out_bytes
        # ---- flops ----
        if ins.opcode == "dot":
            c.flops += _dot_flops(ins, comp)
        elif ins.opcode == "convolution":
            c.flops += 2.0 * out_elems  # lower bound (unused by our models)
        elif ins.opcode in _ELEMENTWISE or ins.opcode == "compare":
            c.flops += out_elems
        elif ins.opcode in ("reduce", "reduce-window"):
            ops = _OPERAND_RE.findall(ins.rest)
            if ops:
                e, _ = shape_elems_bytes(comp.shapes.get(ops[0], ""))
                c.flops += e
        # ---- callees ----
        if ins.opcode == "fusion":
            m = _CALLS_RE.search(ins.rest)
            if m and m.group(1) in comps:
                c.add(_analyze(comps[m.group(1)], comps, memo, True),
                      1.0, count_bytes=False)
        elif ins.opcode == "while":
            trip = 1
            tm = _TRIP_RE.search(ins.rest)
            if tm:
                trip = int(tm.group(1))
            else:
                c.unknown_trip_loops += 1
            for rx in (_BODY_RE, _COND_RE):
                m = rx.search(ins.rest)
                if m and m.group(1) in comps:
                    c.add(_analyze(comps[m.group(1)], comps, memo,
                                   in_fusion), float(trip))
        elif ins.opcode in ("call", "conditional", "async-start"):
            for m in _CALLS_RE.finditer(ins.rest):
                if m.group(1) in comps:
                    c.add(_analyze(comps[m.group(1)], comps, memo,
                                   in_fusion), 1.0)
    memo[key] = c
    return c


def count(hlo_text: str) -> Counts:
    comps = parse_module(hlo_text)
    if "__entry__" not in comps:
        return Counts()
    return _analyze(comps["__entry__"], comps, {}, False)


# ---------------------------------------------------------------------------
# Trace-level (jaxpr) primitive iteration/counting. Interpret-mode
# pallas_calls lower to plain HLO ops, so the kernel-launch regression guard
# ("one FNO block == one pallas_call", analysis/jaxpr_lint.py) must count at
# the jaxpr level, recursing through pjit / custom_vjp / scan / shard_map
# sub-jaxprs. Duck-typed (hasattr) rather than imported so it survives the
# jax.core → jax.extend.core migration (ROADMAP.md §JAX version compat).
# ---------------------------------------------------------------------------
def iter_jaxpr_eqns(jaxpr, into_kernels: bool = True):
    """Yield every eqn of `jaxpr` and of all nested sub-jaxprs (pjit
    bodies, custom_vjp branches, scans, shard_map). into_kernels=False
    stops at pallas_call boundaries: the yielded stream is the
    LAUNCH-level op sequence (each pallas_call appears once; its kernel
    body is not expanded) — the level at which the fusion, cast-ownership,
    and collective contracts are stated (analysis/jaxpr_lint.py)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if eqn.primitive.name == "pallas_call" and not into_kernels:
            continue
        for v in eqn.params.values():
            yield from _iter_sub(v, into_kernels)


def _iter_sub(v, into_kernels):
    if hasattr(v, "jaxpr") and hasattr(v, "consts"):  # ClosedJaxpr
        yield from iter_jaxpr_eqns(v.jaxpr, into_kernels)
    elif hasattr(v, "eqns"):  # Jaxpr
        yield from iter_jaxpr_eqns(v, into_kernels)
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _iter_sub(x, into_kernels)


def jaxpr_primitive_counts(fn, *args, into_kernels: bool = True,
                           **kwargs) -> Dict[str, int]:
    """{primitive name: count} over the full jaxpr of fn(*args), including
    every nested sub-jaxpr (pjit bodies, custom_vjp branches, scans).
    into_kernels=False stops at pallas_call boundaries — the remaining
    count is the LAUNCH-level op count (each pallas_call is one entry, its
    kernel body is not expanded), the fusion claim's "kernel calls"."""
    import jax
    counts: Dict[str, int] = {}
    for eqn in iter_jaxpr_eqns(jax.make_jaxpr(fn)(*args, **kwargs).jaxpr,
                               into_kernels):
        counts[eqn.primitive.name] = counts.get(eqn.primitive.name, 0) + 1
    return counts


def count_pallas_calls(fn, *args, **kwargs) -> int:
    """Number of pallas_call primitives fn(*args) traces to — the
    kernel-launch count of the fused path, robust to interpret mode."""
    return jaxpr_primitive_counts(fn, *args, **kwargs).get("pallas_call", 0)
