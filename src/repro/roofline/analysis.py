"""Three-term roofline analysis from a compiled dry-run artifact.

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

``cost_analysis()`` on the partitioned module reports per-chip flops/bytes.
Collective bytes are NOT in cost_analysis: we parse the post-SPMD HLO text
and sum the result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (shapes in the partitioned
module are already per-shard).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional, Tuple

import numpy as np

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_DTYPE_ALIASES = {"float32": "f32", "bfloat16": "bf16", "float16": "f16",
                  "float64": "f64"}


def dtype_bytes(name: str) -> int:
    """Bytes per element for an HLO short name OR a numpy-style name
    ("float32"/"bfloat16"), so PrecisionPolicy fields plug in directly."""
    return _DTYPE_BYTES[_DTYPE_ALIASES.get(name, name)]

_COLL_RE = re.compile(
    r"=\s*(?:\(([^)]*)\)|(\w+\[[\d,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-chip bytes moved by each collective type (result-shape sizes)."""
    out: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_str = m.group(1) or m.group(2)
        op = m.group(3)
        out[op] = out.get(op, 0) + _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float  # per chip
    hlo_bytes: float  # per chip
    coll_bytes: float  # per chip
    coll_detail: Dict[str, int]
    model_flops: float  # whole-step useful FLOPs (6ND etc.), global
    temp_bytes: int = 0
    arg_bytes: int = 0

    @property
    def t_compute(self) -> float:
        return self.hlo_flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / hw.ICI_LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        """MODEL_FLOPS / (chips · HLO_FLOPs): remat/masking/dispatch waste."""
        denom = self.chips * self.hlo_flops
        return self.model_flops / denom if denom else 0.0

    @property
    def mfu_bound(self) -> float:
        """Model-FLOP utilization achievable at the roofline bound."""
        t = self.t_bound
        if t <= 0:
            return 0.0
        return (self.model_flops / self.chips / t) / hw.PEAK_FLOPS_BF16

    def row(self) -> str:
        d = self.coll_detail
        det = ",".join(f"{k[:2]}:{v/2**20:.0f}M" for k, v in sorted(d.items()))
        return (f"{self.arch:16s} {self.shape:12s} {self.mesh:9s} "
                f"{self.t_compute*1e3:9.2f} {self.t_memory*1e3:9.2f} "
                f"{self.t_collective*1e3:9.2f} {self.bottleneck:10s} "
                f"{self.useful_flop_ratio:7.3f} {self.mfu_bound:6.3f}  {det}")


HEADER = (f"{'arch':16s} {'shape':12s} {'mesh':9s} {'comp_ms':>9s} "
          f"{'mem_ms':>9s} {'coll_ms':>9s} {'bottleneck':10s} "
          f"{'useful':>7s} {'MFU<=':>6s}  collectives")


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float) -> Roofline:
    """Roofline terms from the compiled per-device module.

    Uses the multiplicity-aware HLO counter (roofline/hlo_counter.py):
    XLA's cost_analysis counts while-loop bodies once, understating both
    FLOPs and in-loop collective bytes for scan-over-layers models.
    """
    from repro.roofline import hlo_counter as hc
    ma = compiled.memory_analysis()
    txt = compiled.as_text()
    counts = hc.count(txt)
    coll = {k: int(v) for k, v in counts.coll_bytes.items()}
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        hlo_flops=float(counts.flops),
        hlo_bytes=float(counts.bytes),
        coll_bytes=float(sum(coll.values())),
        coll_detail=coll,
        model_flops=model_flops,
        temp_bytes=getattr(ma, "temp_size_in_bytes", 0),
        arg_bytes=getattr(ma, "argument_size_in_bytes", 0),
    )


# ---------------------------------------------------------------------------
# MODEL_FLOPS (useful-work) estimates
# ---------------------------------------------------------------------------
def lm_model_flops(cfg, shape_kind: str, seq_len: int, global_batch: int
                   ) -> float:
    """6·N_active·tokens for train, 2·N_active·tokens for inference."""
    n_active = cfg.param_count(active_only=True)
    tokens = global_batch * (seq_len if shape_kind != "decode" else 1)
    mult = 6.0 if shape_kind == "train" else 2.0
    return mult * n_active * tokens


def fno_model_flops(cfg, batch: int, *, training: bool = True) -> float:
    """Exact useful FLOPs of the truncated-DFT FNO layer algebra
    (docs/DESIGN.md §3.3), per batch element; training=True multiplies by
    3 for fwd+bwd (train step), training=False is the serving forward.

    Rank-generic (matches the engine's stage order): each forward DFT
    stage transforms one spatial axis n_j→k_j over the pencils formed by
    the other (partially transformed) axes — 4 real-matmul FLOP factors for
    the real first stage, 8 for complex stages; CGEMM is 8·Πk·H·O; the
    inverse chain mirrors the forward with O channels.

    1D (x [H,N], modes K):   rDFT 4·H·N·K | CGEMM 8·K·H·O | irDFT 4·O·N·K
    2D (x [H,X,Y], KX,KY):   rDFT_Y 4·H·X·Y·KY | cDFT_X 8·H·KY·X·KX |
                             CGEMM 8·KX·KY·H·O | icDFT_X 8·O·KY·KX·X |
                             irDFT_Y 4·O·X·KY·Y
    """
    import math
    h = o = cfg.hidden
    sp = math.prod(cfg.spatial)
    lift = cfg.lifting_dim or 2 * h
    r = cfg.ndim
    spatial, modes = list(cfg.spatial), list(cfg.modes)
    cur = list(spatial)

    def stage(ch, ax, real):
        pencils = math.prod(cur) // cur[ax]
        return (4 if real else 8) * ch * pencils * spatial[ax] * modes[ax]

    spectral = stage(h, r - 1, True)  # rDFT along s_R (real input)
    cur[r - 1] = modes[r - 1]
    for ax in range(r - 2, -1, -1):  # cDFT along s_{R-1}…s_1
        spectral += stage(h, ax, False)
        cur[ax] = modes[ax]
    spectral += 8 * math.prod(modes) * h * o  # CGEMM over hidden
    for ax in range(r - 1):  # icDFT along s_1…s_{R-1}
        spectral += stage(o, ax, False)
        cur[ax] = spatial[ax]
    spectral += stage(o, r - 1, True)  # irDFT along s_R (real output)
    if cfg.weight_mode == "per_mode":
        pass  # CGEMM term identical per mode (already counted per-mode)
    # Whole FNO block = spectral + bypass 1x1 GEMM + pointwise epilogue
    # (bias add + residual add + tanh-GELU ≈ 10 flops/elt). Fusion
    # (cfg.fuse_block) moves these into the kernel's k-loop/epilogue but
    # does not change the FLOP count — only the byte model below does.
    per_layer = spectral + 2 * sp * h * o + 12 * sp * o
    lifting = 2 * sp * (cfg.in_channels * lift + lift * h)
    proj = 2 * sp * (h * lift + lift * cfg.out_channels)
    fwd = batch * (cfg.num_layers * per_layer + lifting + proj)
    return (3.0 if training else 1.0) * fwd


def fno_model_bytes(cfg, batch: int, *, variant: str = "full",
                    training: bool = True,
                    fuse_block: bool = None) -> float:
    """Dtype-aware HBM-traffic model of one FNO step (the memory side of
    the roofline — TurboFNO's whole argument is that this term binds).

    Reads cfg.precision (PrecisionPolicy): activations and kernel I/O move
    at the compute dtype, DFT operand bundles at the spectral dtype, dW
    emissions and the AdamW master update at the param dtype — so the
    model predicts the bf16 traffic reduction directly (compute/spectral
    terms halve, master-param terms don't).

    Fused-path accounting per spectral layer: the full-fusion kernel
    touches HBM exactly once per operand (read x, read W, read operands,
    write y — the paper's fusion claim); partial fusion adds the
    inter-launch complex pairs (written once, read once, both directions
    batched into one outer launch per side at rank ≥ 3). Training adds the
    adjoint pipeline (same traffic as forward, dx at the compute dtype)
    and the fused wgrad (re-reads x and gy, writes dW at the param dtype),
    plus the f32 master AdamW update (read params + 2 moments, write all
    three, read grads).

    fuse_block (default: cfg.fuse_block) models the whole-block fusion on
    the full-fusion path: spectral + bypass + bias + GELU in one kernel,
    so the spectral-y / bypass-y / sum / activation intermediates (~4 HBM
    round trips on B·H·∏s tensors per layer, forward alone) never move;
    training keeps three fused kernels (gz recompute, dx adjoint, extended
    wgrad emitting dW + dW_b + dbias in one pass).
    """
    import math
    pol = cfg.precision
    cb = dtype_bytes(pol.compute_dtype)
    pb = dtype_bytes(pol.param_dtype)
    sb = dtype_bytes(pol.spectral_dtype)
    if fuse_block is None:
        fuse_block = getattr(cfg, "fuse_block", False)
    h = o = cfg.hidden
    sp = math.prod(cfg.spatial)
    lift = cfg.lifting_dim or 2 * h
    act = batch * h * sp  # one hidden activation tensor (elements)
    wmul = math.prod(cfg.modes) if cfg.weight_mode == "per_mode" else 1
    wc = 2 * h * o * wmul  # complex spectral weight (re+im)
    byp_w = h * o + o  # bypass 1x1 weight + bias
    mats = 4 * sum(n * k for n, k in zip(cfg.spatial, cfg.modes))

    spectral_fwd = (act + wc + act) * cb + mats * sb
    if variant == "partial" and cfg.ndim >= 2:
        kout = math.prod(cfg.modes[1:])
        inter = 2 * batch * (h + o) * cfg.spatial[0] * kout  # complex pairs
        spectral_fwd += 2 * inter * cb  # write + re-read between launches

    if fuse_block and variant == "full":
        # ONE kernel per block: read x, spectral W, W_b + bias; write the
        # activated output once. Intermediates live only in VMEM.
        per_layer = (2 * act + wc + byp_w) * cb + mats * sb
        if training:
            # gz recompute (reads x, gy, all weights; writes gz) + dx
            # adjoint (reads gz, weights; writes dx) + ONE extended wgrad
            # (reads x, gz; writes dW, dW_b, dbias at the param dtype).
            per_layer += (3 * act + wc + byp_w) * cb + mats * sb
            per_layer += (2 * act + wc + h * o) * cb + mats * sb
            per_layer += 2 * act * cb + (wc + byp_w) * pb
    else:
        # Staged block: spectral kernel + bypass GEMM (read x, W_b + bias,
        # write y_b) + sum (read s, y_b; write z) + GELU (read z, write h).
        per_layer = (spectral_fwd + (2 * act + byp_w) * cb
                     + 3 * act * cb + 2 * act * cb)
        if training:
            # adjoint spectral + spectral wgrad + GELU vjp (read gy, z;
            # write gz) + bypass dx (read gz, W_b; write) + dW_b/dbias
            # (re-read gz, x; emit at param dtype) + cotangent sum.
            per_layer += spectral_fwd + 2 * act * cb + wc * pb
            per_layer += 3 * act * cb
            per_layer += (2 * act + h * o) * cb
            per_layer += 2 * act * cb + byp_w * pb

    io = batch * sp * (cfg.in_channels + cfg.out_channels) * cb
    lift_proj = (2 * batch * sp * (2 * lift + h)
                 + cfg.in_channels * lift + lift * h
                 + h * lift + lift * cfg.out_channels) * cb
    total = cfg.num_layers * per_layer + lift_proj + io
    if training:
        n_params = cfg.param_count()
        total += 7 * n_params * pb  # AdamW: r/w params + 2 moments, read g
    return float(total)


def fno_collective_bytes(cfg, dp: int, tp: int, *, scattered: bool = True,
                         batch: int = 8) -> Dict[str, float]:
    """Per-device ICI wire bytes of the TP collectives in one sharded FNO
    forward (the collective side of the roofline for the DP×TP serve
    path — docs/DESIGN.md §6).

    Each fused block's sharded k-loop produces per-device partial sums of
    the full hidden activation T = (batch/dp)·hidden·∏spatial·compute
    bytes. Completing them costs, per device, on a tp-device ring:

      * ``psum`` (all-reduce, the PR-5 every-layer layout):
        2·(tp-1)/tp · T — reduce-scatter + all-gather under the hood;
      * ``reduce-scatter`` (the scattered layout): (tp-1)/tp · T — the
        interior layer emits the NEXT layer's hidden shard directly and
        skips the gather half, exactly 0.5× the psum wire bytes. The
        ppermute ring (cfg.tp_overlap) moves the same bytes in tp-1
        chunk hops — overlap changes the schedule, not the traffic.

    scattered=True models cfg.tp_layout="scatter": num_layers-1 interior
    reduce-scatters + the final layer's psum (the projection consumes the
    full hidden vector, so the last layer always all-reduces).
    scattered=False models tp_layout="psum": num_layers psums.

    Mirrors the runtime's degradation rules: tp<=1 or hidden % tp != 0
    folds TP away (no collectives — ``make_context``). Returns a dict
    {"interior_per_layer", "final", "total"} so callers can surface the
    per-layer ratio directly (bench_e2e.run_serve's derived column).
    """
    import math
    if tp <= 1 or cfg.hidden % tp != 0:
        return {"interior_per_layer": 0.0, "final": 0.0, "total": 0.0}
    cb = dtype_bytes(cfg.precision.compute_dtype)
    t = (batch / max(dp, 1)) * cfg.hidden * math.prod(cfg.spatial) * cb
    psum = 2.0 * (tp - 1) / tp * t
    interior = ((tp - 1) / tp * t) if scattered else psum
    n_interior = max(cfg.num_layers - 1, 0)
    final = psum if cfg.num_layers > 0 else 0.0
    return {"interior_per_layer": interior, "final": final,
            "total": n_interior * interior + final}
