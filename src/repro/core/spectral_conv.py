"""SpectralConv modules — the FNO Fourier layer with selectable execution
path (ref | xla | pallas) and weight mode (shared | per_mode), rank 1/2/3.

Functional style: ``init(key) -> params``, ``apply(params, x) -> y``.
Channel-first layout [B, C, *spatial], matching the paper. ``apply_*``
accept an optional ``policy`` (PrecisionPolicy) forwarded to the kernels;
init takes the *param* dtype (master weights — f32 under the bf16 preset).
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import PrecisionPolicy
from repro.distributed import sharding as shd
from repro.kernels import ops


def init_spectral_nd(key: jax.Array, in_ch: int, out_ch: int,
                     modes: Sequence[int], weight_mode: str = "shared",
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    """Rank-generic spectral-weight init: W [O,I] shared (the paper's
    CGEMM) or [O,I,k_1..k_R] per-mode (classic FNO)."""
    scale = 1.0 / (in_ch * out_ch) ** 0.5
    shape = ((out_ch, in_ch) if weight_mode == "shared"
             else (out_ch, in_ch) + tuple(modes))
    kr, ki = jax.random.split(key)
    return {"wr": scale * jax.random.normal(kr, shape, dtype),
            "wi": scale * jax.random.normal(ki, shape, dtype)}


def init_spectral_1d(key: jax.Array, in_ch: int, out_ch: int, modes: int,
                     weight_mode: str = "shared",
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return init_spectral_nd(key, in_ch, out_ch, (modes,), weight_mode, dtype)


def apply_spectral_1d(params: Dict[str, jax.Array], x: jax.Array, modes: int,
                      *, path: str = "xla",
                      policy: Optional[PrecisionPolicy] = None,
                      **kw) -> jax.Array:
    """x: [B, C_in, N] -> [B, C_out, N]."""
    return ops.spectral_layer_1d(x, params["wr"], params["wi"], modes,
                                 path=path, policy=policy, **kw)


def init_spectral_2d(key: jax.Array, in_ch: int, out_ch: int,
                     modes: Tuple[int, int], weight_mode: str = "shared",
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return init_spectral_nd(key, in_ch, out_ch, modes, weight_mode, dtype)


def apply_spectral_2d(params: Dict[str, jax.Array], x: jax.Array,
                      modes: Tuple[int, int], *, path: str = "xla",
                      variant: str = "full",
                      policy: Optional[PrecisionPolicy] = None,
                      **kw) -> jax.Array:
    """x: [B, C_in, X, Y] -> [B, C_out, X, Y]."""
    return ops.spectral_layer_2d(x, params["wr"], params["wi"], modes,
                                 path=path, variant=variant, policy=policy,
                                 **kw)


def init_spectral_3d(key: jax.Array, in_ch: int, out_ch: int,
                     modes: Tuple[int, int, int], weight_mode: str = "shared",
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    return init_spectral_nd(key, in_ch, out_ch, modes, weight_mode, dtype)


def apply_spectral_3d(params: Dict[str, jax.Array], x: jax.Array,
                      modes: Tuple[int, int, int], *, path: str = "xla",
                      variant: str = "full",
                      policy: Optional[PrecisionPolicy] = None,
                      **kw) -> jax.Array:
    """x: [B, C_in, X, Y, Z] -> [B, C_out, X, Y, Z]."""
    return ops.spectral_layer_3d(x, params["wr"], params["wi"], modes,
                                 path=path, variant=variant, policy=policy,
                                 **kw)


def apply_fno_block_nd(spec_params: Dict[str, jax.Array],
                       byp_params: Dict[str, jax.Array], x: jax.Array,
                       modes: Sequence[int], *, path: str = "pallas",
                       variant: str = "full",
                       policy: Optional[PrecisionPolicy] = None,
                       tp_layout: str = "psum", tp_overlap: bool = False,
                       ends: Optional[Tuple] = None,
                       **kw) -> jax.Array:
    """One whole FNO block — gelu(spectral(x) + 1×1 bypass + bias) — as a
    single fused kernel on the pallas path (ops.fno_block_nd), any rank.

    spec_params: {"wr","wi"} from init_spectral_nd; byp_params: {"w","b"}
    from core.fno._dense_init, where w is [C_in, C_out] (einsum
    ``bc...,cd->bd...``) — transposed here to the engine's [O,H] layout.

    Inside a multi-device ``sharding_context`` the block dispatches through
    ``ops.fno_block_nd_sharded``: DP over the context's batch axes, TP over
    its model axis — the engine's k-loop hidden contraction — with the TP
    partials completed per tp_layout ("scatter": psum_scatter emitting the
    next layer's hidden shard; "psum": all-reduce to a replicated output —
    docs/DESIGN.md §6). tp_layout/tp_overlap only apply to the sharded
    dispatch; the single-device path ignores them.

    ends: optional (lift, proj) param tuples (``ops.fno_block_ends_nd``)
    folding the model's end MLPs into this block's kernel — single-device
    and pure-DP dispatch only (core.fno guards TP off).
    """
    wb = jnp.swapaxes(byp_params["w"], 0, 1)
    ctx = shd.current_context()
    has_ends = ends is not None and any(e is not None for e in ends)
    if path == "pallas" and ctx is not None and ctx.mesh.devices.size > 1:
        return ops.fno_block_nd_sharded(
            x, spec_params["wr"], spec_params["wi"], wb, byp_params["b"],
            tuple(modes), mesh=ctx.mesh, batch_axes=ctx.batch_axes,
            model_axis=ctx.model_axis, variant=variant, policy=policy,
            tp_layout=tp_layout, tp_overlap=tp_overlap,
            ends=ends if has_ends else None, **kw)
    if has_ends:
        return ops.fno_block_ends_nd(
            x, spec_params["wr"], spec_params["wi"], wb, byp_params["b"],
            tuple(modes), lift=ends[0], proj=ends[1], path=path,
            variant=variant, policy=policy, **kw)
    return ops.fno_block_nd(x, spec_params["wr"], spec_params["wi"], wb,
                            byp_params["b"], tuple(modes), path=path,
                            variant=variant, policy=policy, **kw)
