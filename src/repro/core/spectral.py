"""Spectral-transform algebra: truncated DFTs as MXU-friendly matmuls.

TurboFNO's GPU kernels prune FFT butterflies whose outputs land in discarded
frequency bands. The TPU-native equivalent (docs/DESIGN.md §3.2) computes the
truncated transform as a dense matmul with only the *kept* rows of the DFT
matrix — pruning becomes row selection, truncation/zero-padding become the
matrix shapes, and everything runs on the MXU.

Conventions: transforms act on the LAST axis. Complex tensors are carried as
(real, imag) pairs of real arrays.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# DFT matrix factories (host-side numpy; cached; O(N·k) memory)
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def rdft_mats(n: int, modes: int, dtype: str = "float32") -> Tuple[np.ndarray, np.ndarray]:
    """Forward truncated real-input DFT:  X[m] = sum_n x[n]·e^{-2πi mn/N}.

    Returns (Cr, Ci), each [n, modes], so that for real x[..., n]:
        Xr = x @ Cr,   Xi = x @ Ci.
    """
    assert modes <= n // 2 + 1, (n, modes)
    m = np.arange(modes)[None, :]
    k = np.arange(n)[:, None]
    ang = 2.0 * np.pi * k * m / n
    return (np.cos(ang).astype(dtype), (-np.sin(ang)).astype(dtype))


@functools.lru_cache(maxsize=64)
def irdft_mats(n: int, modes: int, dtype: str = "float32") -> Tuple[np.ndarray, np.ndarray]:
    """Inverse of (truncate ∘ rFFT) with implicit zero padding:

        y[j] = (1/N)·Σ_{m<modes} c_m·(Xr[m]·cos(2πmj/N) − Xi[m]·sin(2πmj/N)),

    with hermitian fold c_0 = 1, c_m = 2 (m ≥ 1, m < N/2), c_{N/2} = 1.
    Returns (Er, Ei), each [modes, n]:  y = Xr @ Er − Xi @ Ei.
    Exactly equals jnp.fft.irfft(zero-pad(X), n).
    """
    assert modes <= n // 2 + 1
    m = np.arange(modes)[:, None]
    j = np.arange(n)[None, :]
    ang = 2.0 * np.pi * m * j / n
    c = np.full((modes, 1), 2.0)
    c[0] = 1.0
    if modes == n // 2 + 1 and n % 2 == 0:
        c[-1] = 1.0  # Nyquist bin is its own conjugate
    return ((c * np.cos(ang) / n).astype(dtype), (c * np.sin(ang) / n).astype(dtype))


@functools.lru_cache(maxsize=64)
def cdft_mats(n: int, modes: int, inverse: bool = False,
              dtype: str = "float32") -> Tuple[np.ndarray, np.ndarray]:
    """Complex truncated DFT matrix.

    forward: F[k, m] = e^{-2πi km/N},  [n, modes]   (keep first `modes` rows)
    inverse: E[m, j] = e^{+2πi mj/N}/N, [modes, n]  (zero-pad implicit)

    NOTE (paper-faithful): TurboFNO keeps only the FIRST dimX fraction of the
    complex axis — positive low frequencies only, no hermitian pair. The
    truncate→pad→inverse round trip is therefore a projection, not identity
    (classic FNO keeps ± corners instead; see docs/DESIGN.md §3.4).
    """
    if not inverse:
        k = np.arange(n)[:, None]
        m = np.arange(modes)[None, :]
        ang = 2.0 * np.pi * k * m / n
        return (np.cos(ang).astype(dtype), (-np.sin(ang)).astype(dtype))
    m = np.arange(modes)[:, None]
    j = np.arange(n)[None, :]
    ang = 2.0 * np.pi * m * j / n
    return ((np.cos(ang) / n).astype(dtype), (np.sin(ang) / n).astype(dtype))


# ---------------------------------------------------------------------------
# Adjoint (transposed) factories — the backward fused pipeline.
#
# The spectral layer is y = Re(((x·C)∘W)·E): a real-linear map whose matrix
# entries are Re(C[n,m]·W[o,h,m]·E[m,j]). Its adjoint w.r.t. x is therefore
# the SAME fused DFT→CGEMM→iDFT pipeline with every DFT operand transposed
# (no conjugation needed — conjugating all factors at once leaves the real
# part unchanged) and the weight transposed over (out, hidden). These
# factories supply the transposed operands in the orientation the fused
# kernels expect.
# ---------------------------------------------------------------------------
@functools.lru_cache(maxsize=64)
def rdft_adjoint_mats(n: int, modes: int, dtype: str = "float32") -> Tuple[np.ndarray, np.ndarray]:
    """Transposed truncated-rDFT operands, each [modes, n].

    Used as the backward pipeline's *inverse*-slot operand: the input
    cotangent ends with dx = Tr @ Crᵀ − Ti @ Ciᵀ.
    """
    cr, ci = rdft_mats(n, modes, dtype)
    return np.ascontiguousarray(cr.T), np.ascontiguousarray(ci.T)


@functools.lru_cache(maxsize=64)
def irdft_adjoint_mats(n: int, modes: int, dtype: str = "float32") -> Tuple[np.ndarray, np.ndarray]:
    """Transposed padded-irDFT operands, each [n, modes].

    Used as the backward pipeline's *forward*-slot operand: the output
    cotangent g enters the spectral domain as G = g @ Erᵀ + i·(g @ Eiᵀ).
    """
    er, ei = irdft_mats(n, modes, dtype)
    return np.ascontiguousarray(er.T), np.ascontiguousarray(ei.T)


@functools.lru_cache(maxsize=64)
def cdft_adjoint_mats(n: int, modes: int, inverse: bool = False,
                      dtype: str = "float32") -> Tuple[np.ndarray, np.ndarray]:
    """Transposed complex-DFT operands.

    forward transposed: [modes, n] (backward inverse slot);
    inverse transposed: [n, modes] (backward forward slot).
    """
    fr, fi = cdft_mats(n, modes, inverse, dtype)
    return np.ascontiguousarray(fr.T), np.ascontiguousarray(fi.T)


# ---------------------------------------------------------------------------
# Rank-generic fused-kernel operand bundles (cached host constants).
#
# The engine (kernels/engine.py) consumes a flat tuple of (real, imag)
# operand pairs: R forward stages in kernel order (axis s_R first, each
# [n, k]) then R inverse stages (axis s_1 first, each [k, n]). adjoint=True
# swaps every operand for its transpose — the backward input-cotangent
# pipeline (see the adjoint-factory comment above). These are lru_cached on
# (spatial, modes, dtype, adjoint, pad) so repeated layer calls/traces stop
# rebuilding the O(N·K) matrices. They return NUMPY arrays on purpose:
# jnp constants created inside a jit trace are tracers, and caching a
# tracer across traces is a leak — numpy constants are constified safely
# by whichever trace consumes them.
# ---------------------------------------------------------------------------
def _pad_np(a: np.ndarray, axis: int, to: int) -> np.ndarray:
    if a.shape[axis] >= to:
        return a
    cfg = [(0, 0)] * a.ndim
    cfg[axis] = (0, to - a.shape[axis])
    return np.pad(a, cfg)


def _fused_mat_pairs(spatial, modes, adjoint, dtype):
    """numpy (mr, mi) pairs: R forward-slot then R inverse-slot operands."""
    r = len(spatial)
    fwd, inv = [], []
    for i in range(r):  # forward stages transform axes s_R, s_{R-1}, …, s_1
        ax = r - 1 - i
        n, k = spatial[ax], modes[ax]
        if ax == r - 1:  # the real-input axis
            fwd.append(irdft_adjoint_mats(n, k, dtype) if adjoint
                       else rdft_mats(n, k, dtype))
        else:
            fwd.append(cdft_adjoint_mats(n, k, True, dtype) if adjoint
                       else cdft_mats(n, k, False, dtype))
    for ax in range(r):  # inverse stages transform axes s_1, …, s_R
        n, k = spatial[ax], modes[ax]
        if ax == r - 1:
            inv.append(rdft_adjoint_mats(n, k, dtype) if adjoint
                       else irdft_mats(n, k, dtype))
        else:
            inv.append(cdft_adjoint_mats(n, k, False, dtype) if adjoint
                       else cdft_mats(n, k, True, dtype))
    return fwd + inv


@functools.lru_cache(maxsize=256)
def fused_operand_mats(spatial: Tuple[int, ...], modes: Tuple[int, ...],
                       dtype: str = "float32", adjoint: bool = False,
                       pad_modes_to: int = 0) -> Tuple[np.ndarray, ...]:
    """Flat operand tuple for the rank-generic fused forward/adjoint
    kernel: (cr,ci) per forward stage then (er,ei) per inverse stage.

    pad_modes_to zero-pads every modes axis up to the given extent (used by
    the rank-1 path, where K is the minor lane dim and must be
    128-aligned); padded rows/cols contribute exactly zero through the
    linear pipeline.
    """
    r = len(spatial)
    dt = jnp.dtype(dtype)
    out = []
    for idx, (mr, mi) in enumerate(_fused_mat_pairs(spatial, modes, adjoint,
                                                    "float32")):
        if pad_modes_to:
            axis = 1 if idx < r else 0  # fwd [n,k] pads cols; inv [k,n] rows
            mr = _pad_np(mr, axis, pad_modes_to)
            mi = _pad_np(mi, axis, pad_modes_to)
        out.append(np.asarray(mr, dt))
        out.append(np.asarray(mi, dt))
    return tuple(out)


@functools.lru_cache(maxsize=256)
def wgrad_operand_mats(spatial: Tuple[int, ...], modes: Tuple[int, ...],
                       dtype: str = "float32",
                       pad_modes_to: int = 0) -> Tuple[np.ndarray, ...]:
    """Flat operand tuple for the fused weight-gradient kernel: R forward
    stages for the primal spectrum A, then R adjoint-forward stages
    (transposed inverse transforms) that push the output cotangent into the
    spectral domain as Ĝ. All [n, k]-oriented, axis s_R first."""
    r = len(spatial)
    dt = jnp.dtype(dtype)
    pairs = (_fused_mat_pairs(spatial, modes, False, "float32")[:r]
             + _fused_mat_pairs(spatial, modes, True, "float32")[:r])
    out = []
    for mr, mi in pairs:
        if pad_modes_to:
            mr = _pad_np(mr, 1, pad_modes_to)
            mi = _pad_np(mi, 1, pad_modes_to)
        out.append(np.asarray(mr, dt))
        out.append(np.asarray(mi, dt))
    return tuple(out)


# ---------------------------------------------------------------------------
# Batched outer-stage operands (partial fusion, rank ≥ 3).
#
# The paper-faithful partial path transforms the outer axes s_2..s_R with
# standalone kernels. Those stages are separable, so their composition is a
# single matmul with the Kronecker product of the per-axis DFT matrices:
# one kernel launch for ALL outer axes instead of one per axis (ROADMAP
# follow-up). Row index = flattened (s_2..s_R) in natural order; column
# index = flattened (k_R..k_2) — the spectrum layout the fused middle
# expects. Built in f32 on host (cast at the call site like every other
# operand), lru_cached, and complex-carried as (real, imag).
# ---------------------------------------------------------------------------
def _kron_ordered(factors):
    """Combine complex per-axis factors F_j[a_j, b_j] (axis order s_2..s_R)
    into M[(a_2..a_R), (b_R..b_2)]."""
    r1 = len(factors)
    subs_in = [f"{chr(97 + 2 * j)}{chr(98 + 2 * j)}" for j in range(r1)]
    rows = "".join(s[0] for s in subs_in)
    cols = "".join(subs_in[j][1] for j in reversed(range(r1)))
    m = np.einsum(",".join(subs_in) + "->" + rows + cols, *factors)
    nr = int(np.prod([f.shape[0] for f in factors]))
    nc = int(np.prod([f.shape[1] for f in factors]))
    return m.reshape(nr, nc)


@functools.lru_cache(maxsize=64)
def outer_fwd_mats(outer_spatial: Tuple[int, ...],
                   outer_modes: Tuple[int, ...],
                   dtype: str = "float32") -> Tuple[np.ndarray, np.ndarray]:
    """Combined forward operand for the outer axes (s_2..s_R): real input,
    truncated spectrum out. [Πn_j, Πk_j], columns ordered (k_R..k_2)."""
    factors = []
    for n, k in zip(outer_spatial, outer_modes):
        fr, fi = cdft_mats(n, k, False, "float64")
        factors.append(fr + 1j * fi)
    m = _kron_ordered(factors)
    return m.real.astype(dtype), m.imag.astype(dtype)


@functools.lru_cache(maxsize=64)
def outer_inv_mats(outer_spatial: Tuple[int, ...],
                   outer_modes: Tuple[int, ...],
                   dtype: str = "float32") -> Tuple[np.ndarray, np.ndarray]:
    """Combined inverse operand for the outer axes: padded complex inverse
    along s_2..s_{R-1} and hermitian-folded real inverse along s_R, real
    output. [Πk_j, Πn_j], rows ordered (k_R..k_2), columns (s_2..s_R);
    consumed as y = Xr@Er − Xi@Ei (only the real part survives)."""
    factors = []
    last = len(outer_spatial) - 1
    for j, (n, k) in enumerate(zip(outer_spatial, outer_modes)):
        er, ei = (irdft_mats(n, k, "float64") if j == last
                  else cdft_mats(n, k, True, "float64"))
        factors.append(er + 1j * ei)
    # _kron_ordered(F_j[a,b]) lays rows out in factor order and columns
    # reversed; feeding the factors reversed (s_R..s_2) therefore yields
    # rows (k_R..k_2) and columns (s_2..s_R).
    m = _kron_ordered(factors[::-1])
    return m.real.astype(dtype), m.imag.astype(dtype)


# ---------------------------------------------------------------------------
# XLA-path transforms (matmul formulation; fused by XLA, no Pallas)
# ---------------------------------------------------------------------------
def truncated_rdft(x: jax.Array, modes: int) -> Tuple[jax.Array, jax.Array]:
    """rFFT along last axis, keeping the first `modes` bins. Real input."""
    n = x.shape[-1]
    cr, ci = rdft_mats(n, modes, "float32")
    cr, ci = jnp.asarray(cr, x.dtype), jnp.asarray(ci, x.dtype)
    f32 = jnp.float32
    return (jax.lax.dot_general(x, cr, (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=f32),
            jax.lax.dot_general(x, ci, (((x.ndim - 1,), (0,)), ((), ())),
                                preferred_element_type=f32))


def padded_irdft(xr: jax.Array, xi: jax.Array, n: int) -> jax.Array:
    """Inverse rFFT from `modes` kept bins, zero-padded to length n."""
    modes = xr.shape[-1]
    er, ei = irdft_mats(n, modes, "float32")
    er, ei = jnp.asarray(er, xr.dtype), jnp.asarray(ei, xr.dtype)
    dims = (((xr.ndim - 1,), (0,)), ((), ()))
    f32 = jnp.float32
    return (jax.lax.dot_general(xr, er, dims, preferred_element_type=f32)
            - jax.lax.dot_general(xi, ei, dims, preferred_element_type=f32))


def truncated_cdft(xr: jax.Array, xi: jax.Array,
                   modes: int) -> Tuple[jax.Array, jax.Array]:
    """Complex DFT along last axis keeping first `modes` bins."""
    n = xr.shape[-1]
    fr, fi = cdft_mats(n, modes, False, "float32")
    fr, fi = jnp.asarray(fr, xr.dtype), jnp.asarray(fi, xr.dtype)
    dims = (((xr.ndim - 1,), (0,)), ((), ()))
    f32 = jnp.float32
    dot = lambda a, b: jax.lax.dot_general(a, b, dims, preferred_element_type=f32)
    return dot(xr, fr) - dot(xi, fi), dot(xr, fi) + dot(xi, fr)


def padded_icdft(xr: jax.Array, xi: jax.Array, n: int) -> Tuple[jax.Array, jax.Array]:
    """Inverse complex DFT from first-`modes` bins zero-padded to n."""
    modes = xr.shape[-1]
    er, ei = cdft_mats(n, modes, True, "float32")
    er, ei = jnp.asarray(er, xr.dtype), jnp.asarray(ei, xr.dtype)
    dims = (((xr.ndim - 1,), (0,)), ((), ()))
    f32 = jnp.float32
    dot = lambda a, b: jax.lax.dot_general(a, b, dims, preferred_element_type=f32)
    return dot(xr, er) - dot(xi, ei), dot(xr, ei) + dot(xi, er)


# ---------------------------------------------------------------------------
# FLOP accounting (paper Fig. 5 analogue — see benchmarks/bench_prune.py)
# ---------------------------------------------------------------------------
def fft_flops(n: int) -> float:
    """Real-op count of a full radix-2 complex FFT (5 N log2 N convention)."""
    return 5.0 * n * np.log2(n)


def pruned_fft_ops(n: int, modes: int) -> int:
    """Butterfly-output count of a DIF FFT pruned to the first `modes` bins.

    Recursive decimation-in-frequency: the top stage produces an even-bin
    branch (sums) and an odd-bin branch (diffs+twiddles); a branch is computed
    only if it feeds a kept bin. Keeping bins [0, k): evens need ceil(k/2),
    odds need floor(k/2). One "op" = one butterfly output (paper Fig. 5
    counting: full 4-point FFT = 8 ops; k=1 → 3 ops (37.5%); k=2 → 6 (75%)).
    """
    if modes <= 0 or n <= 1:
        return 0
    ke, ko = (modes + 1) // 2, modes // 2
    ops = (n // 2 if ke else 0) + (n // 2 if ko else 0)
    return ops + pruned_fft_ops(n // 2, ke) + pruned_fft_ops(n // 2, ko)


def fft_ops(n: int) -> int:
    """Butterfly-output count of the full FFT (same counting as above)."""
    return int(n * np.log2(n))


def pruned_fft_flops(n: int, modes: int) -> float:
    """Pruned-FFT real-op estimate, scaled to the 5·N·log2(N) convention."""
    return fft_flops(n) * pruned_fft_ops(n, modes) / fft_ops(n)


def truncated_dft_matmul_flops(n: int, modes: int, complex_input: bool) -> float:
    """FLOPs of the MXU truncated-DFT formulation (per signal)."""
    mults = 2 if not complex_input else 4
    return 2.0 * mults * n * modes  # 2 flops per MAC
