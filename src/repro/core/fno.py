"""Fourier Neural Operator models (1D / 2D / 3D), built on SpectralConv.

Architecture (paper Fig. 1 / Li et al. 2020):
  lifting pointwise MLP  →  L × [spectral conv + 1x1 bypass conv + GELU]
  →  projection pointwise MLP.

With ``cfg.fuse_block`` each whole block — spectral + bypass + bias +
GELU — runs as ONE pallas_call per layer on the pallas path, forward and
backward (kernels/ops.fno_block_nd); the staged composition below remains
the parity oracle and the only path for ref/xla.

Rank is taken from ``cfg.ndim`` — the 3D variant (Navier–Stokes-class
workloads, Li et al. §5.3) runs on the same rank-generic fused engine as
1D/2D. Functional params-as-pytree; channel-first [B, C, *spatial].

Mixed precision (cfg.precision — a PrecisionPolicy): parameters are
initialized and updated at the *param* dtype (f32 master weights under the
bf16 preset); ``apply_fno`` casts the input once to the compute dtype and
the dense/bypass layers follow the activation dtype, so the whole forward
runs at compute precision while the gradients flowing back to the master
params are upcast by the cast-VJPs. The loss is always reduced in f32.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FNOConfig
from repro.core import spectral_conv as sc
from repro.distributed.sharding import current_context, shard_activation


def _dense_init(key, din, dout, dtype=jnp.float32):
    k1, _ = jax.random.split(key)
    scale = (2.0 / (din + dout)) ** 0.5
    return {"w": scale * jax.random.normal(k1, (din, dout), dtype),
            "b": jnp.zeros((dout,), dtype)}


def _dense(p, x):  # x: [B, C, *sp] pointwise over channels; follows x dtype
    y = jnp.einsum("bc...,cd->bd...", x, p["w"].astype(x.dtype))
    # The bias is broadcast BEFORE the compute-dtype cast: the cast's VJP
    # then upcasts the cotangent to f32 ahead of the broadcast's sum-VJP,
    # so the bias-grad reduction accumulates in f32. (A bf16 reduce over a
    # coherent cotangent field swamps — the accumulator sticks at its
    # first power of two; the weight grads are immune because dot-general
    # VJPs already accumulate in f32.)
    b = p["b"].reshape((1, -1) + (1,) * (y.ndim - 2))
    return y + jnp.broadcast_to(b, y.shape).astype(x.dtype)


def init_fno(key: jax.Array, cfg: FNOConfig) -> Dict[str, Any]:
    cfg.validate()
    dtype = jnp.dtype(cfg.precision.param_dtype)
    lift = cfg.lifting_dim or 2 * cfg.hidden
    keys = jax.random.split(key, 4 + 2 * cfg.num_layers)
    modes = tuple(cfg.modes)
    params: Dict[str, Any] = {
        "lift1": _dense_init(keys[0], cfg.in_channels, lift, dtype),
        "lift2": _dense_init(keys[1], lift, cfg.hidden, dtype),
        "proj1": _dense_init(keys[2], cfg.hidden, lift, dtype),
        "proj2": _dense_init(keys[3], lift, cfg.out_channels, dtype),
        "blocks": [],
    }
    for i in range(cfg.num_layers):
        params["blocks"].append({
            "spectral": sc.init_spectral_nd(keys[4 + 2 * i], cfg.hidden,
                                            cfg.hidden, modes,
                                            cfg.weight_mode, dtype),
            "bypass": _dense_init(keys[5 + 2 * i], cfg.hidden, cfg.hidden,
                                  dtype),
        })
    return params


def apply_fno(params: Dict[str, Any], cfg: FNOConfig, x: jax.Array,
              *, path: str = None, variant: str = "full") -> jax.Array:
    """x: [B, in_channels, *spatial] -> [B, out_channels, *spatial].

    Runs at cfg.precision.compute_dtype (the single activation cast lives
    here; the spectral kernels receive the policy and keep their f32
    accumulators).

    Inside a ``sharding_context`` the ``shard_activation`` calls pin the
    layer boundaries to the DP/TP layout (batch over the data axes, hidden
    over the model axis — docs/DESIGN.md §6); the fused blocks themselves
    dispatch through the shard_map wrapper in ``spectral_conv``."""
    path = path or cfg.path
    pol = cfg.precision
    x = shard_activation(x.astype(jnp.dtype(pol.compute_dtype)), "fno")
    # Whole-block fusion (cfg.fuse_block, pallas path only): spectral +
    # bypass + bias + GELU collapse into ONE pallas_call per layer — the
    # bypass GEMM rides the engine's hidden k-loop and the activation is
    # applied in the iDFT epilogue, so the per-layer intermediates never
    # round-trip HBM. The staged composition below stays the oracle.
    fuse = cfg.fuse_block and path == "pallas"
    # Fused MODEL ENDS (cfg.fuse_ends): fold the lifting MLP into the
    # FIRST fused block kernel and the projection MLP into the LAST one
    # (ops.fno_block_ends_nd) — the boundary activations never round-trip
    # HBM and an L-layer forward still traces exactly L pallas_calls.
    # Single-device / pure-DP only: under TP the projection needs the full
    # post-psum hidden vector and the lift would replicate per shard, so
    # the ends stay staged XLA ops there (DESIGN.md §6).
    ctx = current_context()
    ends_on = fuse and cfg.fuse_ends and (ctx is None
                                          or ctx.model_axis is None)
    if ends_on:
        h = x
    else:
        h = jax.nn.gelu(_dense(params["lift1"], x))
        h = _dense(params["lift2"], shard_activation(h, "fno_lift"))
        h = shard_activation(h, "fno_hidden")
    # An explicit cfg.block_plan pins the kernel launch plans; otherwise
    # the ops layer resolves them from the tuned cache (repro.tuning).
    bkw = {"block_plan": cfg.block_plan} if cfg.block_plan else {}
    last = cfg.num_layers - 1
    mlp = lambda p: (p["w"], p["b"])
    for i, blk in enumerate(params["blocks"]):
        if fuse:
            # TP collective layout per layer position (DESIGN.md §6):
            # interior layers complete their sharded k-loop with a
            # psum_scatter that emits the NEXT layer's hidden shard
            # (cfg.tp_layout="scatter", half the wire bytes of a psum);
            # the FINAL layer always psums — the projection consumes the
            # full hidden vector, so there is no next shard to scatter
            # into. No-op when TP is off.
            layout = cfg.tp_layout if i < last else "psum"
            lift = (mlp(params["lift1"]) + mlp(params["lift2"])
                    if ends_on and i == 0 else None)
            proj = (mlp(params["proj1"]) + mlp(params["proj2"])
                    if ends_on and i == last else None)
            h = sc.apply_fno_block_nd(blk["spectral"], blk["bypass"], h,
                                      tuple(cfg.modes), path=path,
                                      variant=variant, policy=pol,
                                      tp_layout=layout,
                                      tp_overlap=cfg.tp_overlap,
                                      ends=((lift, proj)
                                            if lift or proj else None),
                                      **bkw)
            h = shard_activation(h, "fno" if (ends_on and i == last)
                                 else "fno_hidden")
            continue
        if cfg.ndim == 1:
            s = sc.apply_spectral_1d(blk["spectral"], h, cfg.modes[0],
                                     path=path, policy=pol, **bkw)
        elif cfg.ndim == 2:
            s = sc.apply_spectral_2d(blk["spectral"], h, tuple(cfg.modes),
                                     path=path, variant=variant, policy=pol,
                                     **bkw)
        else:
            s = sc.apply_spectral_3d(blk["spectral"], h, tuple(cfg.modes),
                                     path=path, variant=variant, policy=pol,
                                     **bkw)
        h = jax.nn.gelu(s.astype(h.dtype) + _dense(blk["bypass"], h))
        h = shard_activation(h, "fno_hidden")
    if ends_on:
        return shard_activation(h, "fno")
    out = _dense(params["proj2"], jax.nn.gelu(_dense(params["proj1"], h)))
    return shard_activation(out, "fno")


def relative_l2(pred: jax.Array, target: jax.Array) -> jax.Array:
    """Mean relative L2 loss over the batch (standard FNO objective).

    Always reduced in f32 — the loss is the one place a bf16 sum would
    visibly bias training."""
    pred = pred.astype(jnp.float32)
    target = target.astype(jnp.float32)
    b = pred.shape[0]
    diff = jnp.sqrt(jnp.sum((pred - target).reshape(b, -1) ** 2, axis=-1))
    norm = jnp.sqrt(jnp.sum(target.reshape(b, -1) ** 2, axis=-1))
    return jnp.mean(diff / jnp.maximum(norm, 1e-8))


def fno_loss(params, cfg: FNOConfig, batch: Dict[str, jax.Array],
             *, path: str = None, variant: str = "full") -> jax.Array:
    pred = apply_fno(params, cfg, batch["x"], path=path, variant=variant)
    return relative_l2(pred, batch["y"])
