"""Fault-tolerance runtime: heartbeat watchdog, straggler monitor, elastic
re-mesh.

On a real multi-pod deployment these hooks attach to the coordination
service (missing heartbeat -> evict host -> elastic_restore on survivors).
Here the mechanisms are implemented and unit-tested single-host with
virtual-device meshes; the trainer wires them together.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np


class Watchdog:
    """Fires `on_timeout` if `beat()` isn't called within `timeout_s`.

    One-shot per beat: firing disarms the watchdog until the next
    ``beat()``, and the elapsed-check + disarm happen under the same lock
    ``beat()`` takes — so a heartbeat racing the timeout check can either
    land before it (fresh ``_last``, no fire) or after it (re-arm for the
    NEXT interval), but the watchdog can never double-fire for one stall
    and never fires for a stall a beat already ended.
    """

    def __init__(self, timeout_s: float,
                 on_timeout: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._lock = threading.Lock()
        self._last = time.monotonic()
        self._armed = True
        self._stop = threading.Event()
        self.fired = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def beat(self) -> None:
        with self._lock:
            self._last = time.monotonic()
            self._armed = True

    def _run(self) -> None:
        while not self._stop.wait(self.timeout_s / 4):
            fire = False
            with self._lock:
                if self._armed and \
                        time.monotonic() - self._last > self.timeout_s:
                    self.fired += 1
                    self._armed = False  # one shot until the next beat
                    self._last = time.monotonic()
                    fire = True
            if fire:
                self.on_timeout()

    def stop(self) -> None:
        self._stop.set()


class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than `ratio`× the EMA.

    At fleet scale the same statistic, reported per host, identifies
    persistent stragglers for eviction; here it drives logging and the
    data-pipeline skip policy.
    """

    def __init__(self, ratio: float = 2.0, decay: float = 0.9):
        self.ratio = ratio
        self.decay = decay
        self.ema: Optional[float] = None
        self.flagged: List[int] = []

    def reset(self) -> None:
        """Forget the EMA and the flag history — post-restart reuse: a
        restarted run's first steps (compile, cache warm) must not be
        judged against the pre-restart steady-state EMA."""
        self.ema = None
        self.flagged = []

    def record(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.ratio * self.ema
        if is_straggler:
            self.flagged.append(step)
        # EMA excludes outliers so one straggler doesn't mask the next
        if not is_straggler:
            self.ema = dt if self.ema is None else (
                self.decay * self.ema + (1 - self.decay) * dt)
        return is_straggler


def elastic_restore(checkpointer, step: int, target: Any, new_mesh,
                    spec_fn: Callable[[Any], Any]) -> Any:
    """Restore a checkpoint onto a different mesh (elastic re-scale).

    spec_fn(target) -> PartitionSpec tree for the NEW mesh; leaves are
    device_put with the new shardings — the checkpoint layout is mesh-
    agnostic (full arrays + path manifest), so scaling from e.g. 512 -> 256
    chips after losing a pod is a restore, not a migration.
    """
    from jax.sharding import NamedSharding
    specs = spec_fn(target)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(new_mesh, s), specs,
        is_leaf=lambda s: hasattr(s, "_normalized_spec") or
        type(s).__name__ == "PartitionSpec")
    return checkpointer.restore(step, target, shardings)
