"""Fault-tolerance runtime: heartbeat watchdog, straggler monitor, elastic
re-mesh.

On a real multi-pod deployment these hooks attach to the coordination
service (missing heartbeat -> evict host -> elastic_restore on survivors).
Here the mechanisms are implemented and unit-tested single-host with
virtual-device meshes; the trainer wires them together.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np


class Watchdog:
    """Fires `on_timeout` if `beat()` isn't called within `timeout_s`."""

    def __init__(self, timeout_s: float,
                 on_timeout: Callable[[], None]):
        self.timeout_s = timeout_s
        self.on_timeout = on_timeout
        self._last = time.monotonic()
        self._stop = threading.Event()
        self.fired = 0
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def beat(self) -> None:
        self._last = time.monotonic()

    def _run(self) -> None:
        while not self._stop.is_set():
            time.sleep(self.timeout_s / 4)
            if time.monotonic() - self._last > self.timeout_s:
                self.fired += 1
                self._last = time.monotonic()
                self.on_timeout()

    def stop(self) -> None:
        self._stop.set()


class StragglerMonitor:
    """EMA step-time tracker; flags steps slower than `ratio`× the EMA.

    At fleet scale the same statistic, reported per host, identifies
    persistent stragglers for eviction; here it drives logging and the
    data-pipeline skip policy.
    """

    def __init__(self, ratio: float = 2.0, decay: float = 0.9):
        self.ratio = ratio
        self.decay = decay
        self.ema: Optional[float] = None
        self.flagged: List[int] = []

    def record(self, step: int, dt: float) -> bool:
        is_straggler = self.ema is not None and dt > self.ratio * self.ema
        if is_straggler:
            self.flagged.append(step)
        # EMA excludes outliers so one straggler doesn't mask the next
        if not is_straggler:
            self.ema = dt if self.ema is None else (
                self.decay * self.ema + (1 - self.decay) * dt)
        return is_straggler


def elastic_restore(checkpointer, step: int, target: Any, new_mesh,
                    spec_fn: Callable[[Any], Any]) -> Any:
    """Restore a checkpoint onto a different mesh (elastic re-scale).

    spec_fn(target) -> PartitionSpec tree for the NEW mesh; leaves are
    device_put with the new shardings — the checkpoint layout is mesh-
    agnostic (full arrays + path manifest), so scaling from e.g. 512 -> 256
    chips after losing a pod is a restore, not a migration.
    """
    from jax.sharding import NamedSharding
    specs = spec_fn(target)
    shardings = jax.tree_util.tree_map(
        lambda s: NamedSharding(new_mesh, s), specs,
        is_leaf=lambda s: hasattr(s, "_normalized_spec") or
        type(s).__name__ == "PartitionSpec")
    return checkpointer.restore(step, target, shardings)
