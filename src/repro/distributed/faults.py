"""Deterministic fault-injection harness (docs/DESIGN.md §9).

A ``FaultPlan`` is an explicit, ordered list of ``Fault`` records — *this*
request/step, *this* kind of failure, optionally *this* replica — consumed
exactly once each through explicit hooks in the serving runtime
(``train/serve_runtime.py``) and the trainer (``train/trainer.py``). No
monkeypatching: the production code paths ask the plan "does anything go
wrong here?" at well-defined points, so a chaos run is a pure function of
(plan, seed) and every test / CI gate (``scripts/chaos_smoke.py``) can
assert exact failure counts.

Fault kinds:

  * ``kernel``       — the fused pallas kernel raises (``KernelFault``) for
    one request/step: the degradation-ladder trigger.
  * ``nan``          — the forward's outputs (serving) or the batch
    (training) are poisoned with NaN: the non-finite-guard trigger.
  * ``delay``        — the serving replica (or train step) stalls for
    ``delay_s``: the deadline / watchdog trigger.
  * ``kill``         — the replica dies mid-request: the failover trigger.
  * ``ckpt_io``      — one checkpoint save attempt raises ``IOError``: the
    save-retry trigger.
  * ``corrupt_ckpt`` — not a hook fault: ``corrupt_checkpoint`` flips real
    bytes in a committed step's ``arrays.npz`` so the checksum manifest
    catches it on restore (the reload-rollback trigger).
"""
from __future__ import annotations

import dataclasses
import os
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

KINDS = ("kernel", "nan", "delay", "kill", "ckpt_io", "corrupt_ckpt")
SCOPES = ("serve", "train")


class KernelFault(RuntimeError):
    """A (simulated or classified) kernel-level failure of the fused
    pallas path — the fault class the degradation ladder catches."""


@dataclasses.dataclass(frozen=True)
class Fault:
    """One planned failure.

    ``at`` is the accepted-request index (scope="serve") or the training
    step (scope="train"); ``replica`` narrows a serve fault to one replica
    id (None = whichever replica handles the request)."""

    kind: str
    at: int
    scope: str = "serve"
    replica: Optional[int] = None
    delay_s: float = 0.0

    def __post_init__(self):
        assert self.kind in KINDS, f"unknown fault kind {self.kind!r}"
        assert self.scope in SCOPES, f"unknown fault scope {self.scope!r}"


class FaultPlan:
    """An explicit, deterministic schedule of faults, each fired at most
    once. ``take`` is the single consumption hook: it returns (and marks
    fired) every pending fault matching (scope, at[, kind, replica])."""

    def __init__(self, faults: Sequence[Fault] = ()):
        self.faults: List[Fault] = list(faults)
        self._fired = [False] * len(self.faults)

    def take(self, scope: str, at: int, *, kind: Optional[str] = None,
             replica: Optional[int] = None) -> List[Fault]:
        out: List[Fault] = []
        for i, f in enumerate(self.faults):
            if self._fired[i] or f.scope != scope or f.at != at:
                continue
            if kind is not None and f.kind != kind:
                continue
            if (f.replica is not None and replica is not None
                    and f.replica != replica):
                continue
            self._fired[i] = True
            out.append(f)
        return out

    def pending(self) -> List[Fault]:
        return [f for i, f in enumerate(self.faults) if not self._fired[i]]

    def count(self, *, kinds: Optional[Sequence[str]] = None,
              scope: Optional[str] = None) -> int:
        """Planned (not remaining) faults matching the filter — what the
        chaos gates compare observed stats against."""
        return sum(1 for f in self.faults
                   if (kinds is None or f.kind in kinds)
                   and (scope is None or f.scope == scope))


# ---------------------------------------------------------------------------
# poison helpers (the "inject NaN" faults route through these)
# ---------------------------------------------------------------------------
def poison_output(y) -> np.ndarray:
    """NaN-poison a forward output (host copy — the device value is
    untouched, exactly like a transient numerical blowup in one reply)."""
    out = np.array(y, copy=True)
    out.reshape(-1)[0] = np.nan
    return out


def poison_batch(batch: Dict[str, Any]) -> Dict[str, Any]:
    """NaN-poison the input field of a training batch — the loss and the
    gradients of the poisoned step go NaN, which the trainer's
    non-finite guard must absorb."""
    out = dict(batch)
    x = np.array(batch["x"], copy=True)
    x.reshape(-1)[0] = np.nan
    out["x"] = x
    return out


# ---------------------------------------------------------------------------
# checkpoint corruption (a real on-disk fault, not a hook)
# ---------------------------------------------------------------------------
def corrupt_checkpoint(directory: str, step: int,
                       array: Optional[str] = None) -> str:
    """Flip the payload of one array in ``step_<n>/arrays.npz`` WITHOUT
    updating the manifest — the sha256 check in ``Checkpointer.restore``
    must refuse it (and ``latest_valid_step`` must skip it). Returns the
    corrupted key."""
    path = os.path.join(directory, f"step_{step}", "arrays.npz")
    data = dict(np.load(path))
    key = array if array is not None else sorted(data)[0]
    arr = np.array(data[key], copy=True)
    if arr.size == 0:  # degenerate: corrupt by dtype-preserving resize
        arr = np.zeros((1,), dtype=arr.dtype)
    else:
        flat = arr.reshape(-1)
        flat[0] = (flat[0] + 1.0 if np.issubdtype(arr.dtype, np.floating)
                   else flat[0] + 1)
    data[key] = arr
    np.savez(path, **data)
    return key


# ---------------------------------------------------------------------------
# canned plans (shared by tests, the chaos CI gate, and serve --chaos)
# ---------------------------------------------------------------------------
def standard_chaos_plan() -> FaultPlan:
    """The four-way serving chaos plan the CI gate replays
    (``scripts/chaos_smoke.py``, ``launch/serve_fno.py --chaos``): a
    kernel fault on request 0, a NaN injection on request 1, a replica
    kill on request 2, and a checkpoint corruption (applied on disk by
    the driver after serving, fault record kept here so planned-vs-
    observed counts line up)."""
    return FaultPlan([
        Fault("kernel", at=0),
        Fault("nan", at=1),
        Fault("kill", at=2),
        Fault("corrupt_ckpt", at=3),
    ])


def canned_chaos_plans() -> Dict[str, "FaultPlan"]:
    """Every canned serving chaos plan, by name — the registry the
    conservation tests sweep (``tests/test_resilience.py``): whatever the
    plan injects, ``ResilientServer.STAT_KEYS`` must keep summing to the
    requests offered, and degraded/shed/killed must exactly match the
    plan. Plans are built fresh per call (``FaultPlan`` is stateful —
    fire-once)."""
    return {
        "quiet": FaultPlan([]),
        "standard": standard_chaos_plan(),
        "nan_burst": FaultPlan([Fault("nan", at=0), Fault("nan", at=1),
                                Fault("nan", at=2)]),
        "kill_failover": FaultPlan([Fault("kill", at=0, replica=0),
                                    Fault("kernel", at=2)]),
        "delay": FaultPlan([Fault("delay", at=0, delay_s=0.02),
                            Fault("delay", at=1, delay_s=0.02)]),
    }
