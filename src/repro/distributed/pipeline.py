"""Pipeline parallelism: GPipe schedule over a mesh axis via shard_map +
collective_permute (lax.ppermute).

Stage s owns a contiguous slice of layers; microbatches stream through the
S stages in M + S - 1 ticks. Used for the biggest assigned archs when the
layer-parallel dimension is preferred over pure DP on the "pod" axis; the
schedule and its bubble fraction (S-1)/(M+S-1) are validated against a
sequential reference in tests/test_distributed.py.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def gpipe_forward(stage_fn: Callable[[Any, jax.Array], jax.Array],
                  stage_params: Any, x_mb: jax.Array, *, axis: str,
                  num_stages: int) -> jax.Array:
    """Run inside shard_map over `axis`. stage_params: this stage's params
    (already sharded per-stage); x_mb: [M, mb, ...] microbatches (replicated
    content; stage 0 consumes them). Returns [M, mb, ...] outputs (valid on
    the LAST stage)."""
    s = jax.lax.axis_index(axis)
    m = x_mb.shape[0]
    ticks = m + num_stages - 1
    buf = jnp.zeros_like(x_mb[0])
    out = jnp.zeros_like(x_mb)

    def tick(carry, t):
        buf, out = carry
        # stage 0 ingests microbatch t; others use what arrived last tick
        inp = jnp.where(s == 0,
                        x_mb[jnp.clip(t, 0, m - 1)], buf)
        y = stage_fn(stage_params, inp)
        active = (t - s >= 0) & (t - s < m)
        y = jnp.where(active, y, jnp.zeros_like(y))
        # pass activations downstream s -> s+1 (ring; last wraps to 0, unused)
        perm = [(i, (i + 1) % num_stages) for i in range(num_stages)]
        buf_next = jax.lax.ppermute(y, axis, perm)
        # last stage records its finished microbatch
        out = jnp.where((s == num_stages - 1) & active,
                        out.at[jnp.clip(t - s, 0, m - 1)].set(y), out)
        return (buf_next, out), None

    (buf, out), _ = jax.lax.scan(tick, (buf, out), jnp.arange(ticks))
    # only the last stage holds real outputs (others are zeros); replicate
    return jax.lax.psum(out, axis)


def make_gpipe_fn(stage_fn, *, mesh: Mesh, axis: str, num_stages: int,
                  stage_param_spec, x_spec):
    """shard_map wrapper: returns f(stacked_stage_params, x_mb) -> out.

    Goes through compat_shard_map (the check_rep→check_vma shim, which
    also disables the replication check this schedule needs off — only
    the last stage's outputs are real)."""
    from repro.distributed.sharding import compat_shard_map

    def inner(params, x_mb):
        y = gpipe_forward(stage_fn, params, x_mb, axis=axis,
                          num_stages=num_stages)
        return y

    return compat_shard_map(
        inner, mesh,
        in_specs=(stage_param_spec, x_spec),
        out_specs=x_spec)
