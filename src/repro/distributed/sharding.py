"""Logical-axis sharding: DP / TP / EP / SP rules for the whole framework.

Model code calls ``shard_activation(x, kind)`` at layer boundaries; outside a
``sharding_context`` these are no-ops (CPU unit tests), inside one they become
``with_sharding_constraint`` with specs derived from the mesh and the
architecture (docs/DESIGN.md §6).

FNO strategy: DP shards the batch axis; TP shards the HIDDEN/channel axis —
the fused engine's k-loop contraction axis — whenever the model axis divides
``cfg.hidden``. The TP partial pre-activations are completed by a ``psum``
inside the shard_map dispatch (``kernels.ops.fno_block_nd_sharded``); when
TP is off the model axis folds into the batch axes and the (tiny) FNO
weights replicate (docs/DESIGN.md §6).

TP strategy per architecture (``attn_tp``): attention shards over the "model"
axis when query heads divide it; KV heads are REPLICATED up to one copy per
shard (``kv_repeat``) when ``num_kv_heads < tp`` — this multiplies KV-cache
memory by the repeat factor and is recorded per-arch in EXPERIMENTS.md.
Archs whose head counts don't divide the axis (qwen2 12H, arctic 56H, hymba
25H) replicate attention and use TP for MLP/SSM/vocab only.

Parameter specs are PATH-BASED: ``param_specs(cfg, mesh, params)`` walks the
actual params pytree and assigns a PartitionSpec per leaf from its key path,
so the spec tree always matches the params structure exactly.
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading
from typing import Any, Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import FNOConfig, ModelConfig


@dataclasses.dataclass
class ShardingContext:
    mesh: Mesh
    batch_axes: Tuple[str, ...]  # ("data",) or ("pod", "data") or ()
    model_axis: Optional[str] = "model"
    attn_sharded: bool = True  # heads dim sharded over model axis
    kv_repeat_factor: int = 1  # KV-head replication for TP
    seq_axis: Any = None  # SP: shard sequence/KV-cache over this axis(es)
    resid_seq_axis: Any = None  # Megatron-SP: residual stream seq sharding


_TLS = threading.local()


def compat_shard_map(f, mesh, in_specs, out_specs):
    """Version-safe shard_map (ROADMAP.md §JAX version compat): the entry
    point moved from ``jax.experimental.shard_map`` to ``jax.shard_map``
    and ``check_rep`` was renamed ``check_vma``. Replication checking is
    disabled either way — the FNO dispatch closes over custom_vjp pallas
    wrappers that carry no replication rules."""
    sm = getattr(jax, "shard_map", None)
    if sm is None:
        from jax.experimental.shard_map import shard_map as sm
    for kw in ({"check_vma": False}, {"check_rep": False}, {}):
        try:
            return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)
        except TypeError:
            continue
    raise RuntimeError("no compatible shard_map signature found")


# ---------------------------------------------------------------------------
# Scattered-layout collectives (docs/DESIGN.md §6).
#
# The TP data path completes each interior layer's sharded hidden k-loop
# with a reduce-scatter that emits the NEXT layer's hidden shard directly:
# (tp-1)/tp of the tensor crosses the wire instead of the psum layout's
# 2(tp-1)/tp (reduce + broadcast halves), and the output lands already
# sharded P(batch, model) — no implicit re-shard. ``scatter_sum`` is the
# collective wrapped in a custom_vjp so the backward pass gets the MIRRORED
# collective (an all_gather along the scatter axis — the reduce-scatter's
# exact transpose): jax.grad stays end-to-end differentiable through the
# scattered layout without relying on the primitive's own AD rules.
# ``ring_scatter_sum`` is the same reduction as tp-1 ppermute chunk hops —
# XLA lowers each hop to an async collective-permute it can overlap with
# neighboring k-loop compute (the opt-in ``tp_overlap`` mode; native AD
# transposes the ring into the mirrored all-gather ring).
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2))
def scatter_sum(z: jax.Array, axis_name: str, axis: int = 1) -> jax.Array:
    """Reduce-scatter ``z`` over ``axis_name`` along ``axis`` (tiled): the
    cross-shard sum of z arrives with ``axis`` cut to 1/tp per shard —
    shard i holds chunk i. Must be called inside shard_map."""
    return jax.lax.psum_scatter(z, axis_name, scatter_dimension=axis,
                                tiled=True)


def _scatter_sum_fwd(z, axis_name, axis):
    return scatter_sum(z, axis_name, axis), None


def _scatter_sum_bwd(axis_name, axis, _, g):
    # The mirrored collective: scatter_sum is linear with matrix S·Σ (chunk
    # select ∘ cross-shard sum), whose transpose replicates the per-shard
    # cotangent chunk back to every shard along the scatter axis — exactly
    # a tiled all_gather.
    return (jax.lax.all_gather(g, axis_name, axis=axis, tiled=True),)


scatter_sum.defvjp(_scatter_sum_fwd, _scatter_sum_bwd)


def ring_scatter_sum(z: jax.Array, axis_name: str, axis_size: int,
                     axis: int = 1) -> jax.Array:
    """``scatter_sum`` as a ppermute ring (bidirectionally differentiable
    through ppermute's native transpose).

    Standard ring reduce-scatter: each shard starts from the chunk that is
    furthest (ring-wise) from its own, and over ``axis_size - 1`` steps
    forwards its partial sum to the next shard while adding the local
    chunk the arriving partial corresponds to; after the last hop shard i
    holds Σ_j z_j[chunk_i]. Each hop is an independent async
    collective-permute of 1/tp of the tensor, which XLA's latency-hiding
    scheduler can overlap with unrelated compute — the comm/compute
    overlap lever for the scattered TP layout (``FNOConfig.tp_overlap``).
    """
    n = axis_size
    if n == 1:
        return z
    idx = jax.lax.axis_index(axis_name)
    csize = z.shape[axis] // n

    def chunk(c):
        return jax.lax.dynamic_slice_in_dim(z, c * csize, csize, axis)

    perm = [(j, (j + 1) % n) for j in range(n)]
    acc = chunk((idx + n - 1) % n)
    for s in range(2, n + 1):
        acc = jax.lax.ppermute(acc, axis_name, perm)
        acc = acc + chunk((idx + n - s) % n)
    return acc


def current_context() -> Optional[ShardingContext]:
    return getattr(_TLS, "ctx", None)


@contextlib.contextmanager
def sharding_context(ctx: Optional[ShardingContext]):
    prev = current_context()
    _TLS.ctx = ctx
    try:
        yield ctx
    finally:
        _TLS.ctx = prev


def attn_tp(cfg: ModelConfig, tp: int) -> int:
    """Degree of head-sharding usable for this architecture (tp or 1)."""
    if not cfg.has_attention or tp <= 1:
        return 1
    if cfg.num_heads % tp:
        return 1
    kv = cfg.num_kv_heads
    if kv >= tp and kv % tp == 0:
        return tp
    if kv < tp and tp % kv == 0 and cfg.num_heads % tp == 0:
        # after repeating KV to tp heads, each shard needs whole q-groups
        return tp if (cfg.num_heads // tp) >= 1 and cfg.num_heads % tp == 0 \
            else 1
    return 1


def kv_repeat(cfg: ModelConfig, tp: int) -> int:
    """KV-head replication factor so every TP shard owns whole KV heads."""
    if attn_tp(cfg, tp) == 1 or cfg.num_kv_heads >= tp:
        return 1
    return tp // cfg.num_kv_heads


def make_context(cfg, mesh, *, kind: str = "train",
                 fno_strategy: Optional[str] = None) -> ShardingContext:
    """Standard context for an (arch × step-kind) cell.

    FNO (docs/DESIGN.md §6): DP shards the batch axis and TP shards the
    hidden/channel axis — the fused engine's k-loop contraction axis —
    whenever the model axis divides ``cfg.hidden`` (``fno_strategy`` None
    or "auto"). ``fno_strategy="dp"`` folds the model axis into the batch
    axes instead (weights replicated, no per-layer collective — the right
    call when batch ≫ hidden); indivisible hidden degrades to the same.
    ``kind`` is "train" or "serve" for FNO — the placement is identical,
    FNO serving being a pure batch-throughput forward.
    """
    tp = mesh.shape.get("model", 1)
    pod = "pod" in mesh.shape
    batch: Tuple[str, ...] = ("pod", "data") if pod else ("data",)
    seq_axis = None
    if isinstance(cfg, FNOConfig):
        tp_on = (fno_strategy or "auto") != "dp" and tp > 1 \
            and cfg.hidden % tp == 0
        if not tp_on and "model" in mesh.shape:
            batch = batch + ("model",)
        return ShardingContext(mesh=mesh, batch_axes=batch,
                               model_axis="model" if tp_on else None,
                               attn_sharded=False)
    if isinstance(cfg, ModelConfig):
        a_tp = attn_tp(cfg, tp)
        r = kv_repeat(cfg, tp)
    else:
        a_tp, r = 1, 1
    # Megatron sequence parallelism for training: the residual stream is
    # sequence-sharded over the model axis between layers, so the per-layer
    # saved-for-backward carries scale 1/tp (without it, a 96-layer 18k-wide
    # arch saves 14+ GB/chip of activations at 4k context).
    resid = "model" if (kind == "train" and isinstance(cfg, ModelConfig)) \
        else None
    return ShardingContext(mesh=mesh, batch_axes=batch,
                           attn_sharded=a_tp > 1, kv_repeat_factor=r,
                           resid_seq_axis=resid)


def _batch_entry(ctx: ShardingContext):
    if not ctx.batch_axes:
        return None
    return tuple(ctx.batch_axes) if len(ctx.batch_axes) > 1 \
        else ctx.batch_axes[0]


def activation_spec(kind: str, ctx: ShardingContext) -> Optional[P]:
    b = _batch_entry(ctx)
    m = ctx.model_axis
    s = ctx.seq_axis
    table = {
        "embed": P(b, ctx.resid_seq_axis, None),  # [B, S, D] residual
        "ffn": P(b, s, m),  # [B, S, F]
        "heads": P(b, s, m if ctx.attn_sharded else None, None),
        "logits": P(b, s, m),  # [B, S, V]
        "kv": P(b, s, m if ctx.attn_sharded else None, None),
        "experts": P(b, m, None, None),  # [B, E, C, D] per-row dispatch
        "ssm_inner": P(b, s, m),  # [B, S, d_inner]
        "fno": P(b, None, None, None),  # [B, C_io, *spatial] boundaries
        "fno_hidden": P(b, m, None, None),  # [B, H, *spatial]: H = TP k-loop
        "fno_lift": P(b, m, None, None),  # [B, lift, *spatial] MLP inner
    }
    return table.get(kind)


def shard_activation(x: jax.Array, kind: str) -> jax.Array:
    ctx = current_context()
    if ctx is None:
        return x
    spec = activation_spec(kind, ctx)
    if spec is None:
        return x
    entries = list(spec) + [None] * (x.ndim - len(spec))
    spec = P(*entries[: x.ndim])
    # drop specs that don't divide the dim evenly
    mesh_shape = ctx.mesh.shape
    def ok(dim, entry):
        if entry is None:
            return None
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh_shape.get(a, 1)
        return entry if dim % size == 0 else None
    spec = P(*(ok(d, e) for d, e in zip(x.shape, spec)))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter partition specs (path-based)
# ---------------------------------------------------------------------------
def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _div(n: int, tp: int) -> bool:
    return tp > 0 and n % tp == 0


def _add_fsdp(spec: P, shape, dp: int, start: int = 0, entry="data") -> P:
    """FSDP/ZeRO-3: shard the largest still-replicated weight dim over the
    data axis. Params+optimizer then scale 1/(dp·tp) per chip — without
    this, a 341B arch on a 16x16 mesh replicates 128 GB/chip of state.
    XLA inserts the per-layer weight all-gathers / gradient
    reduce-scatters (they appear in the collective roofline term).
    `start` skips the stacked-layer leading dim."""
    if len(shape) < start + 2:
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    cands = [i for i, (d, e) in enumerate(zip(shape, entries))
             if i >= start and e is None and d % dp == 0 and d >= dp]
    if not cands:
        return spec
    best = max(cands, key=lambda i: shape[i])
    entries[best] = entry
    return P(*entries)


def _lm_leaf_spec(pstr: str, shape, cfg: ModelConfig, tp: int) -> P:
    m = "model"
    a_tp = attn_tp(cfg, tp)
    head_m = m if a_tp > 1 else None
    ff_m = m if _div(cfg.d_ff, tp) else None
    ssm_m = m if _div(cfg.d_inner, tp) else None
    ssmh_m = m if _div(cfg.ssm_heads, tp) else None
    emb_m = m if _div(cfg.vocab_size, tp) else None
    in_layers = pstr.startswith("layers/")
    lead = (None,) if in_layers else ()

    def sp(*tail):
        full = lead + tail
        assert len(full) == len(shape), (pstr, shape, full)
        return P(*full)

    if pstr == "embed":
        return P(emb_m, None)
    if pstr.startswith("lm_head"):
        return P(None, emb_m) if pstr.endswith("w") else P(emb_m)
    if pstr.startswith("final_norm"):
        return P(None)
    # ---- per-layer params (leading stacked dim) ----
    if "/attn/" in pstr:
        if "/wo/" in pstr:
            return sp(head_m, None)
        return sp(None, head_m) if pstr.endswith("/w") else sp(head_m)
    if "/ssm/" in pstr:
        if "/out/" in pstr:
            return sp(ssm_m, None)
        if "/in_x/" in pstr or "/in_z/" in pstr:
            return sp(None, ssm_m) if pstr.endswith("/w") else sp(ssm_m)
        if "/conv_w" in pstr:
            return sp(None, ssm_m)
        if "/a_log" in pstr or pstr.endswith("/ssm/d"):
            return sp(ssmh_m)
        if "/norm/" in pstr:
            return sp(ssm_m)
        if "/in_dt/" in pstr and pstr.endswith("/b"):
            return sp(None)
        return sp(None, None) if len(shape) == 3 else sp(None)
    if "/moe/experts/" in pstr:
        ep = _div(cfg.num_experts, tp)
        e_m = m if ep else None
        f_m = None if ep else ff_m
        if pstr.endswith("wo"):
            return sp(e_m, f_m, None)
        return sp(e_m, None, f_m)
    if "/moe/router/" in pstr:
        return sp(None, None)
    if "/mlp/" in pstr:
        if "/wo/" in pstr:
            return sp(ff_m, None)
        return sp(None, ff_m) if pstr.endswith("/w") else sp(ff_m)
    if "/ln1/" in pstr or "/ln2/" in pstr:
        return sp(None)
    # fallback: replicate
    return P(*([None] * len(shape)))


def _fno_leaf_spec(pstr: str, shape, cfg: FNOConfig, tp: int) -> P:
    """FNO tensor parallelism shards the CONTRACTION (hidden) axis — the
    fused engine's k-loop — so every TP shard computes a partial FNO block
    that ``kernels.ops.fno_block_nd_sharded`` completes with a psum over
    the model axis (docs/DESIGN.md §6). The lifting/projection MLPs follow
    the Megatron column→row pattern around the lifting dim."""
    m = "model"
    h_m = m if _div(cfg.hidden, tp) else None
    lift = cfg.lifting_dim or 2 * cfg.hidden
    l_m = m if _div(lift, tp) else None
    pad = (None,) * max(len(shape) - 2, 0)
    if "spectral" in pstr:  # wr/wi [O, H(, modes...)]: shard H (k-loop)
        return P(None, h_m, *pad)
    if "bypass" in pstr:  # dense [H_in, H_out]: shard the contraction dim
        return P(h_m, None) if pstr.endswith("/w") else P(None)
    if "lift1" in pstr:  # column-parallel into the lifting dim
        return P(None, l_m) if pstr.endswith("/w") else P(l_m)
    if "lift2" in pstr:  # row-parallel back down to hidden
        return P(l_m, None) if pstr.endswith("/w") else P(None)
    if "proj1" in pstr:  # row-parallel over the (sharded) hidden
        return P(h_m, None) if pstr.endswith("/w") else P(None)
    return P(*([None] * len(shape)))  # proj2 + biases: replicate (tiny)


def param_specs(cfg, mesh: Mesh, params, fsdp: bool = True,
                fno_tp: bool = True) -> Any:
    """Spec pytree with the same structure as ``params`` (arrays or SDS).

    fsdp=True additionally shards every weight matrix over the data axis
    (ZeRO-3 for training; 2D weight-stationary sharding for decode of the
    biggest archs — nothing else fits 341B+ on 256 chips).

    fno_tp=False replicates the FNO weights (the pure-DP strategy: the
    model axis is folded into the batch axes by ``make_context``, so the
    hidden axis must not also be sharded over it). Pass
    ``ctx.model_axis is not None`` from a context-driven caller."""
    tp = mesh.shape.get("model", 1)
    dp = mesh.shape.get("data", 1)
    is_lm = isinstance(cfg, ModelConfig)
    leaf_fn = _lm_leaf_spec if is_lm else _fno_leaf_spec
    if not is_lm and not fno_tp:
        tp = 0  # pure-DP FNO: _div() never holds, every leaf replicates
    # >=100B archs extend FSDP across the pod axis too (state /512) —
    # cross-pod weight gathers are the price of fitting at all.
    entry: Any = "data"
    if is_lm and "pod" in mesh.shape and cfg.param_count() > 1e11:
        entry = ("pod", "data")
        dp *= mesh.shape["pod"]

    def assign(path, leaf):
        pstr = _path_str(path)
        spec = leaf_fn(pstr, leaf.shape, cfg, tp)
        if fsdp and is_lm:
            start = 1 if pstr.startswith("layers/") else 0
            spec = _add_fsdp(spec, leaf.shape, dp, start, entry)
        return guard_spec(spec, leaf.shape, mesh)

    return jax.tree_util.tree_map_with_path(assign, params)


def opt_state_specs(cfg, mesh: Mesh, params, opt_state,
                    fno_tp: bool = True) -> Any:
    """AdamW state mirrors param sharding; step is replicated."""
    pspecs = param_specs(cfg, mesh, params, fno_tp=fno_tp)
    return {"m": pspecs, "v": pspecs, "step": P()}


def shardings_from_specs(specs, mesh: Mesh):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------
def guard_spec(spec: P, shape, mesh: Mesh) -> P:
    """Drop spec entries whose mesh-axis product doesn't divide the dim."""
    entries = []
    for dim, entry in zip(shape, tuple(spec) + (None,) * len(shape)):
        if entry is None:
            entries.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        size = 1
        for a in axes:
            size *= mesh.shape.get(a, 1)
        entries.append(entry if dim % size == 0 else None)
    return P(*entries)


def batch_specs(cfg, ctx: ShardingContext, batch_tree) -> Any:
    b = _batch_entry(ctx)

    def assign(path, leaf):
        return guard_spec(P(b, *([None] * (len(leaf.shape) - 1))),
                          leaf.shape, ctx.mesh)

    return jax.tree_util.tree_map_with_path(assign, batch_tree)


def cache_specs(cfg: ModelConfig, ctx: ShardingContext, cache_tree,
                shard_seq: bool = False, seq_axes=None) -> Any:
    """Specs for the decode cache pytree.

    shard_seq=True (SP, long-context batch=1): KV-cache sequence dim over
    the data axis(es). seq_axes overrides the axes used for the sequence
    dim (e.g. ("model",) for big-cache decode where the per-chip KV cache
    would not fit with head sharding alone). SSM states shard heads over
    model when divisible.
    """
    b = _batch_entry(ctx)
    m = ctx.model_axis
    tp = ctx.mesh.shape.get(m, 1)
    kv_m = m if ctx.attn_sharded else None
    if seq_axes is None:
        data_ax = tuple(a for a in ("pod", "data") if a in ctx.mesh.shape)
    else:
        data_ax = tuple(seq_axes)
        shard_seq = True
        if m in data_ax:
            kv_m = None  # model axis now shards the sequence dim
    seq_entry = (data_ax if len(data_ax) > 1 else data_ax[0]) \
        if shard_seq else None
    dp = 1
    for a in (data_ax if shard_seq else ()):
        dp *= ctx.mesh.shape[a]

    def assign(path, leaf):
        pstr = _path_str(path)
        if pstr.endswith("len"):
            return P()
        if pstr.endswith("/k") or pstr.endswith("/v"):
            # [nl, B, Sc, Hkv_eff, D]
            se = seq_entry if (shard_seq and leaf.shape[2] % max(dp, 1) == 0) \
                else None
            kvh = kv_m if leaf.shape[3] % tp == 0 else None
            batch_e = b if (seq_axes is not None and m in (seq_axes or ())
                            ) or not shard_seq else None
            sp = P(None, batch_e, se, kvh, None)
        elif pstr.endswith("/ssm"):
            hm = m if leaf.shape[2] % tp == 0 else None
            sp = P(None, None if shard_seq else b, hm, None, None)
        elif pstr.endswith("/conv"):
            im = m if leaf.shape[3] % tp == 0 else None
            sp = P(None, None if shard_seq else b, None, im)
        else:
            sp = P(*([None] * len(leaf.shape)))
        return guard_spec(sp, leaf.shape, ctx.mesh)

    return jax.tree_util.tree_map_with_path(assign, cache_tree)
