"""Gradient compression: int8 quantization with error feedback.

For DCN-bound multi-pod data parallelism the cross-pod gradient all-reduce
is the dominant collective. ``compress``/``decompress`` give an int8 wire
format (per-tensor absmax scale); ``ef_psum`` wraps a psum with error-
feedback residuals so the quantization error is re-injected next step
(1-bit-Adam-style guarantees). Inside shard_map the quantized tensor is what
crosses the wire conceptually — 4× fewer bytes on the pod axis; the roofline
effect is quantified in EXPERIMENTS.md §Perf.

Scope (ISSUE 8): ``ef_psum`` is deliberately NOT wired into the FNO train
path. ``train/train_step.py`` contains no explicit DP gradient psum — the
step runs under jit with GSPMD sharding, and the compiler inserts the DP
all-reduce itself from the batch-axis sharding of the loss; adding an
explicit ``ef_psum`` inside that step would reduce the gradients TWICE
(once quantized, once by GSPMD). The hook is for explicitly shard_mapped
multi-pod steps where the caller owns the collective — the DCN pod axis —
which this repo's FNO cells (single-pod DP×TP, ICI-bound) never are.
``tests/test_distributed.py::test_fno_train_step_has_no_explicit_psum``
pins the contract: the FNO train step traces zero collectives outside a
sharding context (under a DP context the only traced psums are
shard_map's own weight-grad transposes inside the fused-block dispatch —
still none hand-written in the step).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_psum(g: jax.Array, residual: jax.Array, axis_name: str
            ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback compressed psum of g over `axis_name`.

    Returns (summed gradient, new residual). Call inside shard_map ONLY —
    over an axis whose reduction the caller owns (a multi-pod DCN axis).
    Never call it inside a GSPMD-sharded jit step: the compiler already
    derives the DP gradient all-reduce there, so an explicit ef_psum
    would double-reduce (see the module docstring).
    """
    g32 = g.astype(jnp.float32) + residual
    q, scale = compress(g32)
    deq = decompress(q, scale)
    new_residual = g32 - deq
    return jax.lax.psum(deq, axis_name), new_residual


def tree_ef_psum(grads: Any, residuals: Any, axis_name: str
                 ) -> Tuple[Any, Any]:
    pairs = jax.tree_util.tree_map(
        lambda g, r: ef_psum(g, r, axis_name), grads, residuals)
    summed = jax.tree_util.tree_map(
        lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree_util.tree_map(
        lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return summed, new_res
