"""Gradient compression: int8 quantization with error feedback.

For DCN-bound multi-pod data parallelism the cross-pod gradient all-reduce
is the dominant collective. ``compress``/``decompress`` give an int8 wire
format (per-tensor absmax scale); ``ef_psum`` wraps a psum with error-
feedback residuals so the quantization error is re-injected next step
(1-bit-Adam-style guarantees). Inside shard_map the quantized tensor is what
crosses the wire conceptually — 4× fewer bytes on the pod axis; the roofline
effect is quantified in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_psum(g: jax.Array, residual: jax.Array, axis_name: str
            ) -> Tuple[jax.Array, jax.Array]:
    """Error-feedback compressed psum of g over `axis_name`.

    Returns (summed gradient, new residual). Call inside shard_map.
    """
    g32 = g.astype(jnp.float32) + residual
    q, scale = compress(g32)
    deq = decompress(q, scale)
    new_residual = g32 - deq
    return jax.lax.psum(deq, axis_name), new_residual


def tree_ef_psum(grads: Any, residuals: Any, axis_name: str
                 ) -> Tuple[Any, Any]:
    pairs = jax.tree_util.tree_map(
        lambda g, r: ef_psum(g, r, axis_name), grads, residuals)
    summed = jax.tree_util.tree_map(
        lambda t: t[0], pairs, is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree_util.tree_map(
        lambda t: t[1], pairs, is_leaf=lambda t: isinstance(t, tuple))
    return summed, new_res
