"""Tuning keys and launch plans — the vocabulary of the block autotuner.

A *plan* is a (bb, bo, bh) block-size preference for ONE engine launch
kind; a :class:`LaunchPlans` bundles the five per-launch plans a fused
FNO block's training step needs and travels through the custom_vjps as a
single hashable nondiff argument. Plans are *preferences*: the ops layer
still clamps them to the actual dims at call time (``ops._pick_block``),
which is why the tuning key classes shapes by power-of-two buckets and
excludes the batch size entirely.

Key schema (docs/DESIGN.md §8)::

    r{rank}/{shape_class}/{layout}/{variant}/{dtype}/{launch}
    e.g.  r2/h64-s128x128-m32x32/shared/full/bf16/block_fwd

* ``shape_class`` — hidden (and out, only when it differs), spatial and
  modes extents each rounded UP to the next power of two.
* ``layout`` — "shared" | "per_mode" weight layout.
* ``variant`` — normalized per launch (``launch_variant``): the backward
  launches always key as "full" because the backward pipeline is the
  fully fused adjoint regardless of the forward variant; "core" is the
  partial-fusion middle, so it always keys as "partial".
* ``dtype`` — the policy's compute dtype ("f32"/"bf16"; other dtypes use
  their canonical jnp name).
* ``launch`` — one of :data:`LAUNCH_KINDS`.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple

Triple = Tuple[int, int, int]

LAUNCH_KINDS = ("block_fwd", "core", "gz_recompute", "dx_adjoint", "wgrad")

# The fusion variant each launch kind belongs to in a cache key. Backward
# launches normalize to "full" (one adjoint serves both variants —
# ops._fno_block_vjp_bwd); the partial-fusion middle is the only
# partial-variant kernel with tunable blocks (the outer DFT stages are
# row-blocked standalone kernels outside this tuner's scope).
_LAUNCH_VARIANT = {"block_fwd": "full", "core": "partial",
                   "gz_recompute": "full", "dx_adjoint": "full",
                   "wgrad": "full"}

_DTYPE_TAGS = {"float32": "f32", "bfloat16": "bf16"}


def launch_variant(launch: str) -> str:
    """The normalized variant a launch kind keys under."""
    return _LAUNCH_VARIANT[launch]


def dtype_tag(compute_dtype: str) -> str:
    """Short dtype tag for keys ("float32" → "f32")."""
    return _DTYPE_TAGS.get(compute_dtype, compute_dtype)


def _p2(n: int) -> int:
    return 1 if n <= 1 else 1 << (int(n) - 1).bit_length()


def shape_class(hidden: int, out: int, spatial: Sequence[int],
                modes: Sequence[int]) -> str:
    """Power-of-two shape bucket: plans transfer across nearby shapes, so
    keys class (hidden, spatial, modes) by next-pow2 and omit ``out`` when
    it equals ``hidden`` (the universal case in this repo's FNO stacks).
    Batch is deliberately absent — bb is clamped at call time."""
    parts = [f"h{_p2(hidden)}"]
    if out != hidden:
        parts.append(f"o{_p2(out)}")
    parts.append("s" + "x".join(str(_p2(s)) for s in spatial))
    parts.append("m" + "x".join(str(_p2(m)) for m in modes))
    return "-".join(parts)


def plan_key(rank: int, klass: str, layout: str, dtype: str,
             launch: str) -> str:
    """Format one cache key (the variant segment derives from launch)."""
    return (f"r{rank}/{klass}/{layout}/{launch_variant(launch)}/"
            f"{dtype}/{launch}")


def parse_key(key: str) -> dict:
    """Parse + validate a cache key; raises ValueError with the defect."""
    parts = key.split("/")
    if len(parts) != 6:
        raise ValueError(f"want 6 '/'-separated segments, got {len(parts)}")
    r, klass, layout, variant, dtype, launch = parts
    if not (r.startswith("r") and r[1:].isdigit() and int(r[1:]) in (1, 2, 3)):
        raise ValueError(f"bad rank segment {r!r}")
    if layout not in ("shared", "per_mode"):
        raise ValueError(f"bad layout segment {layout!r}")
    if launch not in LAUNCH_KINDS:
        raise ValueError(f"unknown launch kind {launch!r}")
    if variant != launch_variant(launch):
        raise ValueError(f"variant {variant!r} inconsistent with launch "
                         f"{launch!r} (want {launch_variant(launch)!r})")
    return {"rank": int(r[1:]), "shape_class": klass, "layout": layout,
            "variant": variant, "dtype": dtype, "launch": launch}


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """One resolved (bb, bo, bh) preference plus its provenance."""

    bb: int
    bo: int
    bh: int
    source: str = "default"  # override | cache | default
    key: str = ""            # the cache key it resolved under

    @property
    def triple(self) -> Triple:
        return (self.bb, self.bo, self.bh)


@dataclasses.dataclass(frozen=True)
class LaunchPlans:
    """The five per-launch (bb, bo, bh) preferences one fused FNO block
    carries through its custom_vjp (a single hashable nondiff argument —
    plain int triples only, so equal plans share jit cache entries).

    ``fwd`` drives the full-variant forward (and the spectral-layer-only
    forward, which is the same kernel minus the epilogue operands);
    ``core`` the partial-fusion middle (== ``fwd`` at rank 1, where
    partial degenerates to full); ``gz``/``dx``/``wgrad`` the three
    backward kernels."""

    fwd: Triple
    core: Triple
    gz: Triple
    dx: Triple
    wgrad: Triple

    _FIELD = {"block_fwd": "fwd", "core": "core", "gz_recompute": "gz",
              "dx_adjoint": "dx", "wgrad": "wgrad"}

    @classmethod
    def uniform(cls, triple: Sequence[int]) -> "LaunchPlans":
        t = tuple(int(v) for v in triple)
        return cls(t, t, t, t, t)

    def for_launch(self, launch: str) -> Triple:
        return getattr(self, self._FIELD[launch])

    def with_override(self, bb: int = 0, bo: int = 0,
                      bh: int = 0) -> "LaunchPlans":
        """Apply explicit nonzero components over every launch's plan
        (the public bb/bo/bh=0 'use resolved' contract)."""
        if not (bb or bo or bh):
            return self
        ov = lambda t: (bb or t[0], bo or t[1], bh or t[2])
        return LaunchPlans(ov(self.fwd), ov(self.core), ov(self.gz),
                           ov(self.dx), ov(self.wgrad))


def normalize_override(override: Optional[Sequence[int]]) -> Triple:
    """Canonicalize a user override (None | (bb, bo, bh) with 0 = keep
    resolved) to a concrete triple of ints."""
    if override is None:
        return (0, 0, 0)
    t = tuple(int(v) for v in override)
    if len(t) != 3 or any(v < 0 for v in t):
        raise ValueError(f"block plan override must be 3 non-negative "
                         f"ints, got {override!r}")
    return t
