"""Public block-plan resolution: override → tuned cache → static defaults.

``resolve_launch_plans`` is THE entry point the ops layer (and everything
above it) uses to turn a workload description into the five per-launch
(bb, bo, bh) preferences of a fused FNO block; ``resolve_block_plan``
answers for one launch kind (the serve bucket ladder asks it for the
``block_fwd`` batch block). Resolution order per launch:

1. explicit override — an ``FNOConfig.block_plan`` triple or nonzero
   bb/bo/bh in a public kernel signature (component-wise: 0 keeps the
   resolved value);
2. tuned cache hit (``store.lookup`` under the ``plans.plan_key``
   schema — regenerate with ``scripts/autotune.py``);
3. the documented static fallback ``kernels.ops._BLOCK_DEFAULTS``.

Returned plans are preferences: ``ops._pick_block`` still clamps them to
the actual dims at call time, so tiny trace shapes and ragged batches
never need their own cache entries.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

from repro.tuning import store
from repro.tuning.plans import (BlockPlan, LAUNCH_KINDS, LaunchPlans,
                                dtype_tag, normalize_override, plan_key,
                                shape_class)


def _defaults(rank: int) -> Tuple[int, int, int]:
    from repro.kernels.ops import _BLOCK_DEFAULTS
    return _BLOCK_DEFAULTS[rank]


def _norm_workload(cfg_or_shapes, policy):
    """(hidden, out, spatial, modes, per_mode, policy, cfg_override) from
    an FNOConfig or a (hidden, spatial, modes, per_mode) tuple (the same
    tuple form ``analysis.vmem.block_launch_estimates`` accepts)."""
    from repro.configs.base import FNOConfig
    if isinstance(cfg_or_shapes, FNOConfig):
        cfg = cfg_or_shapes
        return (cfg.hidden, cfg.hidden, tuple(cfg.spatial),
                tuple(cfg.modes), cfg.weight_mode == "per_mode",
                policy or cfg.precision, cfg.block_plan)
    h, spatial, modes, per_mode = cfg_or_shapes
    return (int(h), int(h), tuple(spatial), tuple(modes), bool(per_mode),
            policy, None)


def _resolve_one(rank: int, klass: str, layout: str, dtype: str,
                 launch: str, override: Tuple[int, int, int],
                 cache_path: Optional[str]) -> BlockPlan:
    key = plan_key(rank, klass, layout, dtype, launch)
    cached = store.lookup(key, cache_path)
    base = cached if cached is not None else _defaults(rank)
    source = "cache" if cached is not None else "default"
    bb, bo, bh = (override[0] or base[0], override[1] or base[1],
                  override[2] or base[2])
    if any(override):
        source = "override"
    return BlockPlan(bb, bo, bh, source=source, key=key)


def resolve_launch_plans(rank: int, *, hidden: int, out: Optional[int] = None,
                         spatial: Sequence[int], modes: Sequence[int],
                         per_mode: bool = False, policy=None,
                         override: Optional[Sequence[int]] = None,
                         cache_path: Optional[str] = None) -> LaunchPlans:
    """The five per-launch plans for one fused-block workload (see module
    doc for the resolution order). ``policy`` picks the dtype segment of
    the keys (None → f32). Rank 1 aliases ``core`` to ``fwd`` — partial
    fusion degenerates to full there."""
    out = hidden if out is None else out
    klass = shape_class(hidden, out, spatial, modes)
    layout = "per_mode" if per_mode else "shared"
    dtype = dtype_tag(policy.compute_dtype) if policy is not None else "f32"
    ov = normalize_override(override)
    one = lambda launch: _resolve_one(rank, klass, layout, dtype, launch,
                                      ov, cache_path).triple
    fwd = one("block_fwd")
    return LaunchPlans(fwd=fwd, core=fwd if rank == 1 else one("core"),
                       gz=one("gz_recompute"), dx=one("dx_adjoint"),
                       wgrad=one("wgrad"))


def serve_quantum(cfg_or_shapes, quantum: Optional[int] = None, *,
                  policy=None, cache_path: Optional[str] = None) -> int:
    """The serving bucket quantum, validated against the TUNED plan.

    The bucket ladder (``train/serve_fno_step.bucket_sizes``) must stay a
    multiple of the fused engine's batch block or every bucketed launch
    pads internally — and the batch block is whatever the tuned cache says
    for ``block_fwd``, not the static default. ``quantum=None`` returns
    the tuned ``bb`` itself; an explicit quantum (e.g. already multiplied
    by the DP shard count) is accepted only when it is a positive multiple
    of the tuned ``bb``, so a retune that changes the batch block can
    never silently misalign an explicitly-quantized ladder.
    """
    bb = resolve_block_plan(cfg_or_shapes, "block_fwd", policy=policy,
                            cache_path=cache_path).bb
    if quantum is None:
        return bb
    if quantum < 1 or quantum % bb != 0:
        raise ValueError(
            f"serve quantum {quantum} is not a positive multiple of the "
            f"tuned batch block bb={bb} (block_fwd plan) — the bucket "
            f"ladder would misalign with the kernel grid; use a multiple "
            f"of {bb} or pass quantum=None to take the tuned block")
    return quantum


def resolve_block_plan(cfg_or_shapes, launch: str = "block_fwd", *,
                       policy=None, override: Optional[Sequence[int]] = None,
                       cache_path: Optional[str] = None) -> BlockPlan:
    """Resolve ONE launch kind's plan for a config (or a ``(hidden,
    spatial, modes, per_mode)`` tuple). An ``FNOConfig.block_plan``
    participates as the override unless an explicit ``override`` is
    given. This is the public face of the old ``ops._BLOCK_DEFAULTS``
    lookup — ``train/serve_fno_step.batch_block`` reads ``.bb`` off it.
    """
    if launch not in LAUNCH_KINDS:
        raise ValueError(f"unknown launch {launch!r}; want one of "
                         f"{LAUNCH_KINDS}")
    h, out, spatial, modes, per_mode, pol, cfg_ov = _norm_workload(
        cfg_or_shapes, policy)
    ov = normalize_override(override if override is not None else cfg_ov)
    klass = shape_class(h, out, spatial, modes)
    layout = "per_mode" if per_mode else "shared"
    dtype = dtype_tag(pol.compute_dtype) if pol is not None else "f32"
    rank = len(modes)
    if rank == 1 and launch == "core":
        launch = "block_fwd"
    return _resolve_one(rank, klass, layout, dtype, launch, ov, cache_path)
