"""Block-size autotuner + persisted plan cache (docs/DESIGN.md §8).

The engine's launch geometry is fixed (grid = (B/bb, O/bo, H/bh), hidden
innermost — ``kernels/engine.py``) but the RIGHT (bb, bo, bh) per launch
depends on shapes, weight layout, dtype, and the 16 MiB/core VMEM budget.
This package owns that decision end to end:

* ``plans``   — tuning-key schema + the hashable :class:`LaunchPlans`
  bundle the custom_vjps carry;
* ``resolve`` — ``resolve_block_plan`` / ``resolve_launch_plans``:
  override → tuned cache → static ``_BLOCK_DEFAULTS`` fallback;
* ``store``   — the committed JSON cache (``tuning/cache/blocks.json``)
  and its staleness lint (``check_tuning_cache``, wired into
  ``scripts/lint.py --tuning``);
* ``autotune`` — the TVM/Ansor-shaped generate → VMEM-prune → measure
  search that regenerates the cache (``scripts/autotune.py``,
  ``benchmarks/run.py --autotune``).
"""
from repro.tuning.plans import (BlockPlan, LAUNCH_KINDS, LaunchPlans,
                                plan_key, shape_class)
from repro.tuning.resolve import (resolve_block_plan, resolve_launch_plans,
                                  serve_quantum)
from repro.tuning.store import (DEFAULT_CACHE_PATH, check_tuning_cache,
                                load_cache, save_cache)

__all__ = [
    "BlockPlan", "LAUNCH_KINDS", "LaunchPlans", "plan_key", "shape_class",
    "resolve_block_plan", "resolve_launch_plans", "serve_quantum",
    "DEFAULT_CACHE_PATH",
    "check_tuning_cache", "load_cache", "save_cache",
]
