"""Persisted block-plan cache: committed JSON of autotuned winners.

One file (``tuning/cache/blocks.json`` by default, committed) maps tuning
keys (``plans.plan_key``) to winning (bb, bo, bh) triples plus the
evidence they were chosen on (VMEM estimate, measured wall time, probe
shapes). The loader is mtime-keyed-lru so repeated resolution during a
trace costs one dict lookup, while a regenerated file is picked up
without process restart.

Staleness contract (``check_tuning_cache``, wired into ``scripts/lint.py
--tuning``): the cache's ``meta.engine_signature`` must equal
``kernels.engine.BLOCK_SIGNATURE`` and ``meta.vmem_budget_bytes`` the
current budget — a cache tuned against an older launch geometry or
budget is an error, not a silent fallback. Each entry must parse as a
valid key, carry a positive triple, and (re-estimated against its
recorded probe shapes with the CURRENT estimator) still fit the budget.
"""
from __future__ import annotations

import functools
import json
import os
from typing import Dict, List, Optional, Tuple

from repro.analysis import Finding

CACHE_DIR = os.path.join(os.path.dirname(__file__), "cache")
DEFAULT_CACHE_PATH = os.path.join(CACHE_DIR, "blocks.json")

_EMPTY = {"meta": {}, "entries": {}}


@functools.lru_cache(maxsize=16)
def _load(path: str, mtime_ns: int) -> dict:
    with open(path) as f:
        data = json.load(f)
    data.setdefault("meta", {})
    data.setdefault("entries", {})
    return data


def load_cache(path: Optional[str] = None) -> dict:
    """{"meta": {...}, "entries": {key: {bb,bo,bh,...}}} — empty when the
    file is absent or unparseable (resolution then falls back to the
    static defaults; the staleness lint reports the defect)."""
    path = path or DEFAULT_CACHE_PATH
    try:
        st = os.stat(path)
    except OSError:
        return _EMPTY
    try:
        return _load(path, st.st_mtime_ns)
    except (json.JSONDecodeError, OSError):
        return _EMPTY


def lookup(key: str, path: Optional[str] = None
           ) -> Optional[Tuple[int, int, int]]:
    """The cached winning triple for a key, or None on miss."""
    e = load_cache(path)["entries"].get(key)
    if not e:
        return None
    try:
        t = (int(e["bb"]), int(e["bo"]), int(e["bh"]))
    except (KeyError, TypeError, ValueError):
        return None
    return t if all(v > 0 for v in t) else None


def save_cache(entries: Dict[str, dict], meta: Optional[dict] = None,
               path: Optional[str] = None) -> str:
    """Write a cache file (sorted keys, meta stamped with the current
    engine signature + budget unless overridden) and return its path."""
    from repro.analysis.vmem import VMEM_BUDGET_BYTES
    from repro.kernels.engine import BLOCK_SIGNATURE

    path = path or DEFAULT_CACHE_PATH
    full_meta = {"engine_signature": BLOCK_SIGNATURE,
                 "vmem_budget_bytes": VMEM_BUDGET_BYTES}
    full_meta.update(meta or {})
    data = {"meta": full_meta,
            "entries": {k: entries[k] for k in sorted(entries)}}
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump(data, f, indent=1, sort_keys=False)
        f.write("\n")
    return path


def check_tuning_cache(path: Optional[str] = None) -> List[Finding]:
    """Staleness + integrity lint over one cache file (see module doc)."""
    from repro.analysis.vmem import VMEM_BUDGET_BYTES, launch_estimate
    from repro.configs.base import PrecisionPolicy
    from repro.kernels.engine import BLOCK_SIGNATURE
    from repro.tuning import plans as P

    path = path or DEFAULT_CACHE_PATH
    rel = os.path.relpath(path, os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.dirname(__file__)))))
    if not os.path.exists(path):
        return [Finding("tuning-cache", rel,
                        "no tuned block cache committed — every launch "
                        "falls back to the static defaults (regenerate: "
                        "scripts/autotune.py)", severity="warn")]
    data = load_cache(path)
    if not data["entries"] and not data["meta"]:
        return [Finding("tuning-cache", rel,
                        "cache file exists but is empty/unparseable — "
                        "regenerate with scripts/autotune.py")]

    findings: List[Finding] = []
    sig = data["meta"].get("engine_signature")
    if sig != BLOCK_SIGNATURE:
        findings.append(Finding(
            "tuning-cache", rel,
            f"engine signature mismatch: cache tuned against {sig!r} but "
            f"the engine is {BLOCK_SIGNATURE!r} — the launch geometry "
            f"changed; regenerate with scripts/autotune.py"))
    budget = data["meta"].get("vmem_budget_bytes")
    if budget != VMEM_BUDGET_BYTES:
        findings.append(Finding(
            "tuning-cache", rel,
            f"budget mismatch: cache assumed {budget} bytes VMEM, current "
            f"budget is {VMEM_BUDGET_BYTES} — winners may not fit; "
            f"regenerate with scripts/autotune.py"))

    for key, e in data["entries"].items():
        try:
            parsed = P.parse_key(key)
        except ValueError as exc:
            findings.append(Finding("tuning-cache", f"{rel}::{key}",
                                    f"unparseable key: {exc}"))
            continue
        triple = lookup(key, path)
        if triple is None:
            findings.append(Finding(
                "tuning-cache", f"{rel}::{key}",
                f"entry must carry positive integer bb/bo/bh, got "
                f"{ {k: e.get(k) for k in ('bb', 'bo', 'bh')} }"))
            continue
        probe = e.get("probe")
        if not probe:
            findings.append(Finding("tuning-cache", f"{rel}::{key}",
                                    "entry lacks the probe shapes needed "
                                    "to re-check feasibility"))
            continue
        # Refit against the CURRENT estimator: a winner that no longer
        # fits means the byte model (or kernel) moved under the cache.
        pol = PrecisionPolicy.from_name(parsed["dtype"])
        est = launch_estimate(
            (int(probe["hidden"]), tuple(probe["spatial"]),
             tuple(probe["modes"]), parsed["layout"] == "per_mode"),
            parsed["launch"], triple, batch=int(probe.get("batch", 8)),
            policy=pol)
        if est.total_bytes > VMEM_BUDGET_BYTES:
            findings.append(Finding(
                "tuning-cache", f"{rel}::{key}",
                f"stale winner: {triple} now estimates "
                f"{est.total_bytes / 2**20:.1f} MiB for its probe shapes "
                f"(> {VMEM_BUDGET_BYTES / 2**20:.0f} MiB budget) — the "
                f"estimator or engine changed; regenerate the cache"))
    return findings
