"""The block-size autotuner: generate → VMEM-prune → measure → persist.

TVM/Ansor-shaped search specialized to the fused FNO engine's tiny
3-parameter launch space (docs/DESIGN.md §8). For every tuning key the
config matrix can emit (all ``FNO_IDS`` × {full, reduced} × {f32, bf16}
× launch kinds):

1. **generate** the candidate grid — bb ∈ {1, 2, 4, 8}, bo/bh ∈ {8, 16,
   32, 64, 128}, plus the rank's static default — clamped to the probe
   dims (``ops._pick_block``) and deduped on the effective triple;
2. **prune** statically with ``analysis.vmem.launch_estimate`` against
   ``VMEM_BUDGET_BYTES`` — the estimator is deliberately a floor, so
   anything it rejects is certainly infeasible on hardware;
3. **measure** the top-K surviving candidates (static score: least pad
   waste, then largest bo/bh/bb) with the bench harness
   (``benchmarks.common.time_fn``) over jit-wrapped interpret-mode
   launches — only for probes small enough to interpret
   (``hidden·∏spatial ≤ MEASURE_ELEMS``; the full-size 2D/3D grids keep
   their statically-scored winner, flagged ``source: "estimated"``);
4. **persist** winners + evidence (VMEM estimate, wall time, probe
   shapes) via ``store.save_cache`` — the committed
   ``tuning/cache/blocks.json`` that ``resolve_launch_plans`` reads.

Entry points: :func:`tune` (library), ``scripts/autotune.py`` (CLI),
``benchmarks/run.py --autotune`` (bench hook).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.tuning import plans as P
from repro.tuning import store

# A candidate interpret-mode measurement is only meaningful (and
# affordable) when the probe activation is small: hidden·∏spatial in
# elements. Reduced configs and the full-size 1D config qualify; the
# full-size 2D/3D grids are statically scored.
MEASURE_ELEMS = 131_072

_BB_GRID = (1, 2, 4, 8)
# bo/bh down to 1: the big full-size spatial grids (fno3d keeps 2·bh·∏s
# f32 elements of x windows resident) are only VMEM-feasible with bh < 8,
# trading MXU tile width for fitting the budget at all.
_BOH_GRID = (1, 2, 4, 8, 16, 32, 64, 128)
_TOP_K = 3
_PROBE_BATCH = 8


@dataclasses.dataclass(frozen=True)
class Workload:
    """One tunable workload = one (shape class, layout, dtype) cell."""

    label: str  # e.g. "fno2d/reduced"
    rank: int
    hidden: int
    spatial: Tuple[int, ...]
    modes: Tuple[int, ...]
    per_mode: bool
    dtype: str  # "f32" | "bf16"

    @property
    def klass(self) -> str:
        return P.shape_class(self.hidden, self.hidden, self.spatial,
                             self.modes)

    @property
    def layout(self) -> str:
        return "per_mode" if self.per_mode else "shared"

    @property
    def elems(self) -> int:
        n = self.hidden
        for s in self.spatial:
            n *= s
        return n

    @property
    def launches(self) -> Tuple[str, ...]:
        # Rank 1 has no distinct core launch: partial fusion degenerates
        # to full there and the resolver aliases core → block_fwd.
        if self.rank == 1:
            return tuple(k for k in P.LAUNCH_KINDS if k != "core")
        return P.LAUNCH_KINDS

    def policy(self):
        from repro.configs.base import PrecisionPolicy
        return PrecisionPolicy.from_name(self.dtype)


def tunable_workloads(smoke: bool = False) -> List[Workload]:
    """Every (shape class, layout, dtype) the config matrix can emit,
    deduped (e.g. reduced fno2d and reduced fno2d-large share a cell).
    ``smoke`` keeps only the reduced shapes — a seconds-long CI pass."""
    from repro.configs import FNO_IDS, get_config

    out: List[Workload] = []
    seen = set()
    for arch in FNO_IDS:
        variants = [(get_config(arch, reduced=True), "reduced")]
        if not smoke:
            variants.append((get_config(arch), "full"))
        for cfg, tag in variants:
            for dtype in ("f32", "bf16"):
                w = Workload(
                    label=f"{arch}/{tag}", rank=cfg.ndim, hidden=cfg.hidden,
                    spatial=tuple(cfg.spatial), modes=tuple(cfg.modes),
                    per_mode=cfg.weight_mode == "per_mode", dtype=dtype)
                cell = (w.rank, w.klass, w.layout, w.dtype)
                if cell not in seen:
                    seen.add(cell)
                    out.append(w)
    return out


def _candidates(w: Workload) -> List[Tuple[int, int, int]]:
    """Candidate grid, clamped to the probe dims and deduped on the
    effective triple (two preferences that clamp to the same launch are
    the same candidate)."""
    from repro.kernels.ops import _BLOCK_DEFAULTS, _pick_block

    raw = list(itertools.product(_BB_GRID, _BOH_GRID, _BOH_GRID))
    raw.append(_BLOCK_DEFAULTS[w.rank])
    seen, out = set(), []
    for bb, bo, bh in raw:
        eff = (_pick_block(_PROBE_BATCH, bb), _pick_block(w.hidden, bo),
               _pick_block(w.hidden, bh))
        if eff not in seen:
            seen.add(eff)
            out.append(eff)
    return out


def _pad_waste(w: Workload, t: Tuple[int, int, int]) -> float:
    """Fractional compute overhead from padding each gridded dim up to a
    block multiple (bb against the probe batch; bo/bh against hidden)."""
    def frac(dim, b):
        return (-dim % b) / dim

    return (frac(_PROBE_BATCH, t[0]) + frac(w.hidden, t[1])
            + frac(w.hidden, t[2]))


def _static_rank(w: Workload, feasible):
    """Least pad waste first, then the largest bo (widest MXU output
    tile), bh (longest k-loop windows), bb (fewest batch launches)."""
    return sorted(feasible, key=lambda e: (
        _pad_waste(w, e[0]), -e[0][1], -e[0][2], -e[0][0]))


def _feasible(w: Workload) -> Dict[str, List[Tuple[Tuple[int, int, int],
                                                   int]]]:
    """Per launch kind: (triple, est_bytes) for every candidate that
    fits the budget."""
    from repro.analysis.vmem import VMEM_BUDGET_BYTES, launch_estimate

    shapes = (w.hidden, w.spatial, w.modes, w.per_mode)
    pol = w.policy()
    out: Dict[str, List] = {}
    for launch in w.launches:
        fits = []
        for t in _candidates(w):
            est = launch_estimate(shapes, launch, t, batch=_PROBE_BATCH,
                                  policy=pol)
            if est.total_bytes <= VMEM_BUDGET_BYTES:
                fits.append((t, est.total_bytes))
        out[launch] = fits
    return out


# ---------------------------------------------------------------------------
# Measurement: jit-wrapped interpret-mode launches over a shared probe.
# ---------------------------------------------------------------------------
def _probe_arrays(w: Workload):
    import jax
    import jax.numpy as jnp

    pol = w.policy()
    cp = jnp.dtype(pol.compute_dtype)
    h, r = w.hidden, w.rank
    ks = [jax.random.PRNGKey(i) for i in range(6)]
    x = jax.random.normal(ks[0], (_PROBE_BATCH, h) + w.spatial, cp)
    wshape = (h, h) + (w.modes if w.per_mode else ())
    wr = jax.random.normal(ks[1], wshape, cp) * 0.1
    wi = jax.random.normal(ks[2], wshape, cp) * 0.1
    wb = jax.random.normal(ks[3], (h, h), cp) * 0.1
    bias = jax.random.normal(ks[4], (h,), cp) * 0.1
    gy = jax.random.normal(ks[5], x.shape, cp)
    return x, wr, wi, wb, bias, gy


def _launch_fn(w: Workload, launch: str, triple: Tuple[int, int, int]):
    """A jitted zero-arg closure running ONE launch of the given kind at
    the probe shapes — the same internal entry points the custom_vjps
    call, so measured time ranks real launches."""
    import jax
    import jax.numpy as jnp

    from repro.kernels import ops

    x, wr, wi, wb, bias, gy = _probe_arrays(w)
    bb, bo, bh = triple
    pol = w.policy()
    modes = w.modes

    if launch == "block_fwd":
        def fn():
            return ops._fnond_fused(x, wr, wi, modes, bb, bo, bh, True, pol,
                                    wb=wb, bias=bias, act="gelu")
    elif launch == "core":
        def fn():
            return ops._fnond_partial(x, wr, wi, modes, bb, bo, bh, True,
                                      pol)
    elif launch == "gz_recompute":
        def fn():
            return ops._fnond_fused(x, wr, wi, modes, bb, bo, bh, True, pol,
                                    wb=wb, bias=bias, gy=gy, act="gelu_vjp")
    elif launch == "dx_adjoint":
        def fn():
            return ops._fnond_fused(
                gy, jnp.swapaxes(wr, 0, 1), jnp.swapaxes(wi, 0, 1), modes,
                bb, bo, bh, True, pol, adjoint=True,
                wb=jnp.swapaxes(wb, 0, 1))
    else:  # wgrad
        def fn():
            return ops._fnond_wgrad(x, gy, modes, bb, bo, bh, True,
                                    per_mode=w.per_mode, pol=pol,
                                    with_bypass=True)
    return jax.jit(fn)


def _measure(w: Workload, launch: str, triple, iters: int) -> float:
    import sys

    bench = _bench_dir()
    if bench not in sys.path:  # the harness is a top-level dir, not a pkg
        sys.path.insert(0, bench)
    from common import time_fn

    fn = _launch_fn(w, launch, triple)
    return time_fn(fn, warmup=1, iters=iters)


def _bench_dir() -> str:
    import os

    return os.path.join(os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__))))), "benchmarks")


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------
def tune(measure: str = "auto", smoke: bool = False,
         out: Optional[str] = None, iters: int = 5,
         log=print) -> Tuple[str, Dict[str, dict]]:
    """Run the full search and persist the cache. ``measure``: "auto"
    (probes under :data:`MEASURE_ELEMS` get wall-timed), "all" (force
    timing everywhere — slow off-TPU), "none" (static scores only —
    the CI smoke mode). Returns (cache_path, entries)."""
    assert measure in ("auto", "all", "none"), measure
    entries: Dict[str, dict] = {}
    for w in tunable_workloads(smoke=smoke):
        feasible = _feasible(w)
        timed = measure == "all" or (measure == "auto"
                                     and w.elems <= MEASURE_ELEMS)
        for launch in w.launches:
            key = P.plan_key(w.rank, w.klass, w.layout, w.dtype, launch)
            if key in entries:
                continue
            fits = _static_rank(w, feasible[launch])
            if not fits:
                log(f"  !! {key}: NO feasible candidate — key left to the "
                    f"static fallback")
                continue
            entry = {"probe": {"batch": _PROBE_BATCH, "hidden": w.hidden,
                               "spatial": list(w.spatial),
                               "modes": list(w.modes)},
                     "workload": w.label}
            if timed:
                best_us, best = None, None
                for t, est in fits[:_TOP_K]:
                    us = _measure(w, launch, t, iters)
                    log(f"  {key}: {t} -> {us:.0f}us "
                        f"({est / 2**20:.1f} MiB est)")
                    if best_us is None or us < best_us:
                        best_us, best = us, (t, est)
                entry.update(bb=best[0][0], bo=best[0][1], bh=best[0][2],
                             est_bytes=best[1], us=round(best_us, 1),
                             source="measured")
            else:
                t, est = fits[0]
                log(f"  {key}: {t} ({est / 2**20:.1f} MiB est, static)")
                entry.update(bb=t[0], bo=t[1], bh=t[2], est_bytes=est,
                             source="estimated")
            entries[key] = entry
    path = store.save_cache(entries, meta={"measure": measure,
                                           "smoke": smoke}, path=out)
    log(f"wrote {len(entries)} entries -> {path}")
    return path, entries
