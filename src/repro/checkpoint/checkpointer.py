"""Checkpointing: atomic, checksummed, async, reshard-on-restore.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json  (tmp-dir + rename for
atomicity; sha256 per array for corruption detection). ``restore`` accepts a
target sharding pytree so a checkpoint written on one mesh restores onto a
DIFFERENT mesh (elastic restart: lose a pod, re-mesh, continue).

Single-host I/O here; on a real multi-host pod each host writes its own
addressable shards under the same step dir — the manifest/atomic-rename
protocol is unchanged (process 0 commits the rename after a barrier).
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self.last_error: Optional[Exception] = None
        # Crash-leftover sweep: a save killed mid-write leaves its
        # .tmp_step_* dir behind (the atomic rename never happened).
        # Stale tmp dirs are garbage by construction — no reader ever
        # sees them — so reclaim the disk on startup.
        for name in os.listdir(directory):
            if name.startswith(".tmp_step_"):
                shutil.rmtree(os.path.join(directory, name),
                              ignore_errors=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = True) -> None:
        flat = _flatten(tree)  # device->host copy happens here, synchronously
        if blocking:
            self._write(step, flat)
        else:
            self.wait()
            self._thread = threading.Thread(
                target=self._write_safe, args=(step, flat), daemon=True)
            self._thread.start()

    def _write_safe(self, step: int, flat):
        try:
            self._write(step, flat)
        except Exception as e:  # surfaced on next wait()
            self.last_error = e

    def _write(self, step: int, flat: Dict[str, np.ndarray]) -> None:
        tmp = os.path.join(self.dir, f".tmp_step_{step}")
        final = os.path.join(self.dir, f"step_{step}")
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "checksums": {k: hashlib.sha256(v.tobytes()).hexdigest()
                          for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.rename(tmp, final)  # atomic commit
        self._gc()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.last_error is not None:
            err, self.last_error = self.last_error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    def verify(self, step: int) -> bool:
        """True iff step's manifest parses and every array matches its
        sha256 — the integrity predicate behind ``latest_valid_step``."""
        d = os.path.join(self.dir, f"step_{step}")
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
            data = np.load(os.path.join(d, "arrays.npz"))
            checksums = manifest["checksums"]
            if set(data.files) != set(checksums):
                return False
            return all(
                hashlib.sha256(data[k].tobytes()).hexdigest() == checksums[k]
                for k in data.files)
        except Exception:  # unreadable/corrupt step is just invalid
            return False

    def latest_valid_step(self) -> Optional[int]:
        """Newest step that passes ``verify`` — the restore entry point
        for callers that must survive a corrupt/truncated checkpoint
        (trainer restarts, the serving runtime's hot reload): corrupt
        steps are skipped, not fatal."""
        for step in reversed(self.steps()):
            if self.verify(step):
                return step
        return None

    def restore(self, step: int, target: Any,
                shardings: Optional[Any] = None) -> Any:
        """Restore into the structure of `target` (SDS or arrays). If
        `shardings` given, device_put each leaf with it (resharding)."""
        d = os.path.join(self.dir, f"step_{step}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        for k in data.files:
            h = hashlib.sha256(data[k].tobytes()).hexdigest()
            if h != manifest["checksums"][k]:
                raise IOError(f"checkpoint corruption in {k!r} at step {step}")

        leaves_p, treedef = jax.tree_util.tree_flatten_with_path(target)
        keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path) for path, _ in leaves_p]
        shard_leaves = (jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda s: hasattr(s, "spec"))
            if shardings is not None else [None] * len(keys))
        out = []
        for key, (path, leaf), sh in zip(keys, leaves_p, shard_leaves):
            arr = data[key]
            if sh is not None:
                arr = jax.device_put(arr.astype(leaf.dtype), sh)
            else:
                arr = jax.numpy.asarray(arr, dtype=leaf.dtype)
            out.append(arr)
        return jax.tree_util.tree_unflatten(treedef, out)
