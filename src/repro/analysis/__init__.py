"""Contract linter: machine-checked enforcement of the repo's durable
design contracts (ROADMAP.md §Durable design contracts, docs/DESIGN.md §7).

Two layers, one ``Finding`` currency:

  * ``analysis.jaxpr_lint`` — trace lints: checkers that trace production
    entry points (fwd/grad of ``kernels.ops.fno_block_nd``, the sharded
    dispatch, ``FNOServer.step_fn``) and walk the jaxpr to assert the
    fusion contract (pallas_call counts), cast ownership
    (``convert_element_type`` only at the boundaries the active
    ``PrecisionPolicy`` allows), and the collective budget (one ``psum``
    per TP layer, zero all-gathers on the serve path).
  * ``analysis.vmem`` — static VMEM-footprint estimator for the engine's
    launches (scratch + operand bytes from the block-size table and
    dtype), flagging over-budget configs before lowering.
  * ``analysis.ast_lint`` — source lints: AST rules for the compat policy
    (every ``pl.pallas_call`` through ``_compiler_params``, every
    shard_map through ``compat_shard_map``, no raw ``jnp.fft`` on
    production paths, no dtype literals outside allowlisted cast
    boundaries) plus the config-registry audit (every seeded arch either
    builds a cell or carries a non-empty skip_reason).

``scripts/lint.py --all`` sweeps the full matrix (ranks 1-3 × weight
layouts × fusion variants × f32/bf16 × DP/TP) and is wired into
``scripts/check.sh`` and CI. This module stays import-light (no jax) so
the AST layer can run anywhere.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List


@dataclasses.dataclass(frozen=True)
class Finding:
    """One contract violation (or, at severity="warn", a flagged risk).

    checker: short rule id (e.g. "pallas-count", "cast-ownership");
    target: what was checked (an entry point, a file:line, a config id);
    message: the pointed, human-actionable violation description.
    """

    checker: str
    target: str
    message: str
    severity: str = "error"  # "error" fails the lint; "warn" is reported

    def __str__(self) -> str:
        tag = "WARN" if self.severity == "warn" else "FAIL"
        return f"[{tag} {self.checker}] {self.target}: {self.message}"


def errors(findings: Iterable[Finding]) -> List[Finding]:
    return [f for f in findings if f.severity == "error"]


def format_findings(findings: Iterable[Finding]) -> str:
    return "\n".join(str(f) for f in findings)
