"""Trace-level contract lints over production entry points.

Each checker traces a function with ``jax.make_jaxpr`` and walks the
launch-level jaxpr (``roofline.hlo_counter.iter_jaxpr_eqns`` with
``into_kernels=False`` — pallas_call bodies are opaque, exactly the level
the contracts are stated at):

  * **pallas-count** — the fusion contract (ROADMAP.md, DESIGN.md §3.4):
    one FNO block forward on the full-fusion path == ONE pallas_call,
    jax.grad of the block == exactly FOUR (fwd + gz recompute + dx adjoint
    + extended wgrad), a fused model forward / serve step == num_layers.
  * **cast-ownership** — DESIGN.md §4: launch-level
    ``convert_element_type`` ops between float dtypes may only move
    between the dtypes the active ``PrecisionPolicy`` names (so the f32
    preset admits NO float↔float casts, the bf16 preset only f32↔bf16);
    anything else is a stray cast that would silently change numerics.
  * **collective-budget** — DESIGN.md §6: on the scattered TP layout one
    ``psum_scatter`` per interior layer emits the next layer's hidden
    shard and only the FINAL layer completes with a ``psum``; the legacy
    psum layout budgets one ``psum`` per layer; pure DP budgets zero of
    either; and never an explicit all_gather / all_to_all / ppermute on
    the FNO forward or serve path (the opt-in ring-overlap variant, which
    trades the one psum_scatter for tp-1 ppermutes, is smoke-checked by
    ``scripts/overlap_smoke.py`` rather than budgeted here).

``lint_*`` drivers sweep the production matrix (ranks 1-3 × weight
layouts × fusion variants × f32/bf16 × DP/TP); ``scripts/lint.py`` is the
CLI. ``fused_block_contract`` / ``serve_step_contract`` are the thin
wrappers behind ``scripts/fused_block_smoke.py`` and the serve driver's
inline assert.
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.analysis import Finding
from repro.configs.base import PrecisionPolicy
from repro.roofline.hlo_counter import iter_jaxpr_eqns

# Explicit cross-device primitives a trace can contain. GSPMD-inserted
# collectives (post-trace) are invisible here by design: the contract
# governs the collectives the code *asks for*, i.e. the shard_map psum.
COLLECTIVE_PRIMS = ("psum", "all_gather", "all_to_all", "ppermute",
                    "psum_scatter", "reduce_scatter")

DTYPES = ("f32", "bf16")
LAYOUTS = ("shared", "per_mode")
VARIANTS = ("full", "partial")


# ---------------------------------------------------------------------------
# jaxpr walkers
# ---------------------------------------------------------------------------
def launch_eqns(fn, *args, **kwargs) -> list:
    """All launch-level eqns of fn(*args, **kwargs) (pallas_call opaque)."""
    closed = jax.make_jaxpr(fn)(*args, **kwargs)
    return list(iter_jaxpr_eqns(closed.jaxpr, into_kernels=False))


def pallas_count(fn, *args, **kwargs) -> int:
    return sum(1 for e in launch_eqns(fn, *args, **kwargs)
               if e.primitive.name == "pallas_call")


def float_casts(fn, *args, **kwargs) -> List[Tuple[str, str]]:
    """Launch-level float→float ``convert_element_type`` (src, dst) dtype
    name pairs. Same-dtype and int/bool converts are not casts in the
    cast-ownership sense and are dropped."""
    out: List[Tuple[str, str]] = []
    for eqn in launch_eqns(fn, *args, **kwargs):
        if eqn.primitive.name != "convert_element_type":
            continue
        src = jnp.dtype(eqn.invars[0].aval.dtype)
        dst = jnp.dtype(eqn.params["new_dtype"])
        if src == dst:
            continue
        if not (jnp.issubdtype(src, jnp.floating)
                and jnp.issubdtype(dst, jnp.floating)):
            continue
        out.append((src.name, dst.name))
    return out


def collective_counts(fn, *args, **kwargs) -> Dict[str, int]:
    counts: Dict[str, int] = {}
    for eqn in launch_eqns(fn, *args, **kwargs):
        name = eqn.primitive.name
        if name in COLLECTIVE_PRIMS:
            counts[name] = counts.get(name, 0) + 1
    return counts


def allowed_casts(policy: PrecisionPolicy) -> frozenset:
    """The float↔float cast pairs a policy legitimizes: any move between
    the dtypes the policy itself names (plus f32 — master weights and the
    loss reduction are always f32, DESIGN.md §4). The f32 preset therefore
    allows NO float casts; bf16 allows exactly f32↔bf16."""
    ds = {policy.param_dtype, policy.compute_dtype, policy.spectral_dtype,
          policy.accum_dtype, policy.grad_acc_dtype, "float32"}
    ds = {jnp.dtype(d).name for d in ds}
    return frozenset((a, b) for a in ds for b in ds if a != b)


# ---------------------------------------------------------------------------
# checkers
# ---------------------------------------------------------------------------
def check_pallas_count(fn, args: Sequence, want: int, *, target: str,
                       kwargs: Optional[dict] = None) -> List[Finding]:
    got = pallas_count(fn, *args, **(kwargs or {}))
    if got == want:
        return []
    return [Finding(
        "pallas-count", target,
        f"traced {got} pallas_calls, want exactly {want} — the fusion "
        f"contract (one fused kernel per block fwd, 4 per grad, one per "
        f"layer at model level) is broken")]


def check_cast_ownership(fn, args: Sequence, policy: PrecisionPolicy, *,
                         target: str,
                         kwargs: Optional[dict] = None) -> List[Finding]:
    allowed = allowed_casts(policy)
    bad = [c for c in float_casts(fn, *args, **(kwargs or {}))
           if c not in allowed]
    if not bad:
        return []
    uniq = sorted(set(bad))
    shown = ", ".join(f"{s}->{d}" for s, d in uniq)
    return [Finding(
        "cast-ownership", target,
        f"{len(bad)} stray launch-level float cast(s) outside the "
        f"PrecisionPolicy boundaries: {shown} (policy allows "
        f"{sorted(set(a for a, _ in allowed)) or ['no float casts']}; "
        f"see DESIGN.md §4 for who owns each cast)")]


def check_collective_budget(fn, args: Sequence, *, psums: int, target: str,
                            psum_scatters: int = 0,
                            kwargs: Optional[dict] = None) -> List[Finding]:
    """Budget the explicit collectives a traced path may contain.

    psums: full all-reduces (one per TP layer on the psum layout; exactly
    one — the final layer's — on the scattered layout). psum_scatters:
    reduce-scatters emitting the next layer's hidden shard (one per
    INTERIOR TP layer on the scattered layout, zero otherwise).
    ``lax.psum_scatter`` traces as the ``reduce_scatter`` primitive on
    JAX 0.4.x — both spellings count toward the same budget. Anything
    else (all_gather, all_to_all, ppermute) is unexpected on the FNO
    forward/serve path.
    """
    counts = collective_counts(fn, *args, **(kwargs or {}))
    findings = []
    got = counts.pop("psum", 0)
    if got != psums:
        findings.append(Finding(
            "collective-budget", target,
            f"traced {got} psum(s), want exactly {psums} (scattered "
            f"layout: only the final TP layer psums; psum layout: one per "
            f"TP layer; zero under pure DP — DESIGN.md §6)"))
    got_rs = counts.pop("psum_scatter", 0) + counts.pop("reduce_scatter", 0)
    if got_rs != psum_scatters:
        findings.append(Finding(
            "collective-budget", target,
            f"traced {got_rs} psum_scatter(s), want exactly "
            f"{psum_scatters} (one per INTERIOR TP layer on the scattered "
            f"layout, emitting the next layer's hidden shard — "
            f"DESIGN.md §6)"))
    if counts:
        shown = ", ".join(f"{k}×{v}" for k, v in sorted(counts.items()))
        findings.append(Finding(
            "collective-budget", target,
            f"unexpected collective(s) on a path budgeted for psum/"
            f"psum_scatter only: {shown}"))
    return findings


# ---------------------------------------------------------------------------
# production entry-point builders (tiny shapes — these only trace)
# ---------------------------------------------------------------------------
_SPATIAL = {1: (16,), 2: (8, 8), 3: (8, 6, 6)}
_MODES = {1: (5,), 2: (3, 4), 3: (2, 3, 2)}


def _policy(dtype: str) -> PrecisionPolicy:
    return PrecisionPolicy.from_name(dtype)


def block_args(rank: int, layout: str, dtype: str):
    """(x, wr, wi, wb, bias) for one fno_block_nd trace at production
    boundary dtypes: x at the compute dtype (apply_fno casts the input
    once at the top), weights at the param dtype (master weights)."""
    pol = _policy(dtype)
    cp = jnp.dtype(pol.compute_dtype)
    pp = jnp.dtype(pol.param_dtype)
    b, h, o = 2, 4, 4
    modes = _MODES[rank]
    wshape = (o, h) + (modes if layout == "per_mode" else ())
    x = jnp.zeros((b, h) + _SPATIAL[rank], cp)
    wr = jnp.zeros(wshape, pp)
    wi = jnp.zeros(wshape, pp)
    wb = jnp.zeros((o, h), pp)
    bias = jnp.zeros((o,), pp)
    return x, wr, wi, wb, bias


def expected_block_calls(rank: int, variant: str) -> Tuple[int, int]:
    """(fwd, grad) pallas_call counts for one block. Full fusion is one
    kernel; the paper-faithful partial variant runs outer-fwd + core +
    outer-inv for rank ≥ 2 (rank 1 has no outer stages). The backward is
    always the fused adjoint: gz recompute + dx + extended wgrad = +3."""
    fwd = 1 if (variant == "full" or rank == 1) else 3
    return fwd, fwd + 3


def lint_block_matrix(ranks: Sequence[int] = (1, 2, 3),
                      layouts: Sequence[str] = LAYOUTS,
                      variants: Sequence[str] = VARIANTS,
                      dtypes: Sequence[str] = DTYPES) -> List[Finding]:
    """fwd + grad of ``ops.fno_block_nd`` across the whole single-device
    matrix: pallas counts and cast ownership."""
    from repro.kernels import ops

    findings: List[Finding] = []
    for rank, layout, variant, dtype in itertools.product(
            ranks, layouts, variants, dtypes):
        target = f"fno_block_nd r{rank}/{layout}/{variant}/{dtype}"
        pol = _policy(dtype)
        modes = _MODES[rank]
        args = block_args(rank, layout, dtype)
        blk = lambda *a: ops.fno_block_nd(  # noqa: E731
            *a, modes, path="pallas", variant=variant, policy=pol)
        loss = lambda *a: jnp.sum(blk(*a) ** 2)  # noqa: E731
        grad = lambda *a: jax.grad(  # noqa: E731
            loss, argnums=(0, 1, 2, 3, 4))(*a)
        want_fwd, want_grad = expected_block_calls(rank, variant)
        findings += check_pallas_count(blk, args, want_fwd,
                                       target=f"{target} fwd")
        findings += check_pallas_count(grad, args, want_grad,
                                       target=f"{target} grad")
        findings += check_cast_ownership(blk, args, pol,
                                         target=f"{target} fwd")
        findings += check_cast_ownership(grad, args, pol,
                                         target=f"{target} grad")
    return findings


def lint_model(archs: Sequence[str] = ("fno1d", "fno2d", "fno3d"),
               dtypes: Sequence[str] = DTYPES) -> List[Finding]:
    """Whole fused-model forward (``apply_fno`` with fuse_block): exactly
    num_layers pallas_calls and policy-clean casts."""
    from repro.configs import get_config
    from repro.configs.fno import with_fuse_block, with_precision
    from repro.core import fno as fno_mod

    findings: List[Finding] = []
    for arch, dtype in itertools.product(archs, dtypes):
        cfg = with_fuse_block(
            with_precision(get_config(arch, reduced=True), dtype), True)
        target = f"apply_fno {arch}/fuse_block/{dtype}"
        params = jax.eval_shape(lambda: fno_mod.init_fno(
            jax.random.PRNGKey(0), cfg))
        params = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params)
        x = jnp.zeros((2, cfg.in_channels) + tuple(cfg.spatial))
        model = lambda p, xx: fno_mod.apply_fno(  # noqa: E731
            p, cfg, xx, path="pallas")
        findings += check_pallas_count(model, (params, x), cfg.num_layers,
                                       target=target)
        findings += check_cast_ownership(model, (params, x), cfg.precision,
                                         target=target)
    return findings


def _mesh_or_finding(dp: int, tp: int, target: str):
    from repro.launch.mesh import make_compat_mesh
    need = dp * tp
    if jax.device_count() < need:
        return None, [Finding(
            "collective-budget", target,
            f"skipped: needs {need} devices, have {jax.device_count()} "
            f"(run under XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{need}, as scripts/lint.py does)", severity="warn")]
    return make_compat_mesh((dp, tp), ("data", "model")), []


def lint_sharded_blocks(mesh_grids: Sequence[Tuple[int, int]] = ((8, 1),
                                                                 (4, 2)),
                        dtypes: Sequence[str] = DTYPES,
                        layouts: Sequence[str] = ("psum", "scatter")
                        ) -> List[Finding]:
    """``fno_block_nd_sharded`` under DP and DP×TP, both TP layouts: still
    one pallas_call per shard, exactly one psum (psum layout) or exactly
    one psum_scatter (scattered layout) iff TP is on, policy-clean
    casts."""
    from repro.kernels import ops

    findings: List[Finding] = []
    for (dp, tp), dtype, layout in itertools.product(mesh_grids, dtypes,
                                                     layouts):
        if tp == 1 and layout != layouts[0]:
            continue  # layouts coincide under pure DP — lint once
        target = f"fno_block_nd_sharded dp{dp}xtp{tp}/{dtype}/{layout}"
        mesh, fs = _mesh_or_finding(dp, tp, target)
        findings += fs
        if mesh is None:
            continue
        pol = _policy(dtype)
        rank = 2
        modes = _MODES[rank]
        x, wr, wi, wb, bias = block_args(rank, "shared", dtype)
        x = jnp.zeros((dp * 2,) + x.shape[1:], x.dtype)  # batch % dp == 0
        fn = lambda *a: ops.fno_block_nd_sharded(  # noqa: E731
            *a, modes, mesh=mesh, batch_axes=("data",),
            model_axis="model", policy=pol, tp_layout=layout)
        args = (x, wr, wi, wb, bias)
        scat = layout == "scatter" and tp > 1
        findings += check_pallas_count(fn, args, 1, target=target)
        findings += check_collective_budget(
            fn, args, psums=1 if (tp > 1 and not scat) else 0,
            psum_scatters=1 if scat else 0, target=target)
        findings += check_cast_ownership(fn, args, pol, target=target)
    return findings


def lint_serve(arch: str = "fno2d",
               mesh_grids: Sequence[Tuple[int, int]] = ((8, 1), (4, 2)),
               dtypes: Sequence[str] = DTYPES,
               layouts: Sequence[str] = ("scatter", "psum")
               ) -> List[Finding]:
    """``FNOServer.step_fn`` through the shard_map dispatch, both TP
    layouts: num_layers pallas_calls; on the scattered layout one
    psum_scatter per interior layer and ONE psum on the final layer, on
    the psum layout one psum per layer (iff TP); zero all-gathers, clean
    casts."""
    from repro.configs import get_config
    from repro.configs.fno import with_precision
    from repro.core import fno as fno_mod
    from repro.distributed import sharding as shd
    from repro.train import serve_fno_step as sfs

    findings: List[Finding] = []
    for (dp, tp), dtype, layout in itertools.product(mesh_grids, dtypes,
                                                     layouts):
        if tp == 1 and layout != layouts[0]:
            continue  # layouts coincide under pure DP — lint once
        target = f"FNOServer.step_fn {arch} dp{dp}xtp{tp}/{dtype}/{layout}"
        mesh, fs = _mesh_or_finding(dp, tp, target)
        findings += fs
        if mesh is None:
            continue
        cfg = with_precision(get_config(arch, reduced=True), dtype)
        import dataclasses
        cfg = dataclasses.replace(cfg, path="pallas", fuse_block=True,
                                  tp_layout=layout)
        ctx = shd.make_context(cfg, mesh, kind="serve")
        params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
        server = sfs.FNOServer(cfg, params, ctx=ctx, max_batch=2)
        xb = jnp.zeros((server.buckets[0], cfg.in_channels)
                       + tuple(cfg.spatial), jnp.float32)
        args = (params, {"x": xb})
        tp_on = ctx.model_axis is not None
        scat = tp_on and layout == "scatter"
        findings += check_pallas_count(server.step_fn, args, cfg.num_layers,
                                       target=target)
        findings += check_collective_budget(
            server.step_fn, args,
            psums=(1 if scat else cfg.num_layers) if tp_on else 0,
            psum_scatters=cfg.num_layers - 1 if scat else 0, target=target)
        findings += check_cast_ownership(server.step_fn, args,
                                         cfg.precision, target=target)
    return findings


def lint_rollout(archs: Sequence[str] = ("fno1d", "fno2d", "fno3d"),
                 dtypes: Sequence[str] = DTYPES,
                 ks: Sequence[int] = (1, 4)) -> List[Finding]:
    """The rollout trace contract (DESIGN.md §10): a K-step device-
    resident rollout (``FNOServer.rollout_step_fn`` — one ``lax.scan``
    whose body is the fused forward) traces EXACTLY ``num_layers``
    pallas_calls regardless of K, because the scan body traces once. An
    unrolled per-step loop would trace K × num_layers — K kernel-launch
    sets and K HBM round-trips of the carry — which is precisely the
    staged dispatch the rollout tier exists to eliminate. Casts stay
    policy-owned: the single carry cast at the top is the policy's own
    input cast, and every scan iteration reuses the carry dtype."""
    import dataclasses
    import functools

    from repro.configs import get_config
    from repro.configs.fno import with_precision
    from repro.core import fno as fno_mod
    from repro.train import serve_fno_step as sfs

    findings: List[Finding] = []
    for arch, dtype in itertools.product(archs, dtypes):
        cfg = dataclasses.replace(
            with_precision(get_config(arch, reduced=True), dtype),
            path="pallas", fuse_block=True)
        params = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            jax.eval_shape(lambda: fno_mod.init_fno(
                jax.random.PRNGKey(0), cfg)))
        server = sfs.FNOServer(cfg, params, max_batch=2)
        xb = jnp.zeros((server.buckets[0], cfg.in_channels)
                       + tuple(cfg.spatial), jnp.float32)
        args = (params, {"x": xb})
        for k in ks:
            target = f"FNOServer.rollout_step_fn {arch}/{dtype} K={k}"
            fn = functools.partial(server.rollout_step_fn, steps=k)
            findings += check_pallas_count(fn, args, cfg.num_layers,
                                           target=target)
            findings += check_cast_ownership(fn, args, cfg.precision,
                                             target=target)
    return findings


def lint_resilient_serve(arch: str = "fno2d",
                         dtypes: Sequence[str] = DTYPES) -> List[Finding]:
    """The resilience contract at trace level (DESIGN.md §9): the
    ``ResilientServer`` production step stays EXACTLY the fused serving
    step — num_layers pallas_calls, no extra collectives — while the
    degraded (XLA-oracle) step contains ZERO pallas_calls and zero
    explicit collectives. The fallback lives in its own jit entry; if it
    ever leaked into the hot trace (or started launching kernels itself)
    the degradation ladder would be serving the very path it is supposed
    to be a refuge from."""
    import dataclasses

    from repro.configs import get_config
    from repro.configs.fno import with_precision
    from repro.core import fno as fno_mod
    from repro.train import serve_runtime as srt

    findings: List[Finding] = []
    for dtype in dtypes:
        cfg = dataclasses.replace(
            with_precision(get_config(arch, reduced=True), dtype),
            path="pallas", fuse_block=True)
        params = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            jax.eval_shape(lambda: fno_mod.init_fno(
                jax.random.PRNGKey(0), cfg)))
        rs = srt.ResilientServer(cfg, params, replicas=1, max_batch=2)
        xb = jnp.zeros((rs.primary.buckets[0], cfg.in_channels)
                       + tuple(cfg.spatial), jnp.float32)
        args = (params, {"x": xb})
        tgt = f"ResilientServer {arch}/{dtype}"
        findings += check_pallas_count(
            rs.primary.step_fn, args, cfg.num_layers,
            target=f"{tgt} production step")
        findings += check_pallas_count(
            rs.fallback.step_fn, args, 0, target=f"{tgt} degraded step")
        findings += check_collective_budget(
            rs.fallback.step_fn, args, psums=0,
            target=f"{tgt} degraded step")
        findings += check_cast_ownership(
            rs.fallback.step_fn, args, cfg.precision,
            target=f"{tgt} degraded step")
    return findings


# ---------------------------------------------------------------------------
# thin-wrapper entry points for the existing CI guards
# ---------------------------------------------------------------------------
def fused_block_contract() -> List[Finding]:
    """The PR-4 trace-count guard as framework checks: block fwd == 1,
    grad == 4, reduced fno2d fused model == num_layers pallas_calls
    (scripts/fused_block_smoke.py wraps this)."""
    findings = lint_block_matrix(ranks=(2,), layouts=("shared",),
                                 variants=("full",), dtypes=("f32",))
    findings += lint_model(archs=("fno2d",), dtypes=("f32",))
    return findings


def serve_step_contract(server, cfg) -> List[Finding]:
    """The serve driver's fusion-contract assert (one pallas_call per
    layer through the shard_map dispatch) as a framework check."""
    xb = jnp.zeros((server.buckets[0], cfg.in_channels)
                   + tuple(cfg.spatial), jnp.float32)
    return check_pallas_count(
        server.step_fn, (server.params, {"x": xb}), cfg.num_layers,
        target=f"{cfg.name} serve step")
