"""Static VMEM-footprint estimator for the fused engine's launches.

Computes each engine launch's operand + scratch bytes from the resolved
block plans (``repro.tuning.resolve_launch_plans`` — tuned cache with the
static ``kernels.ops._BLOCK_DEFAULTS`` as fallback), the config's shapes,
and the ``PrecisionPolicy`` dtypes — BEFORE lowering, so an over-budget
config is a lint finding instead of a Mosaic allocation failure mid-run.
The estimator is also the autotuner's pruning oracle
(``launch_estimate`` scores one candidate triple for one launch kind).

Shape model (mirrors ``kernels/engine.py`` exactly):

  * grid-blocked operands and outputs (the x/w/y/wb/bias/gy windows) are
    double-buffered by Mosaic → ×2 bytes;
  * the DFT operand mats use constant index maps (same block every
    program) and the VMEM accumulators are scratch → ×1;
  * accumulators live at ``accum_dtype`` with the shapes the kernels
    declare (``rev_modes+(bb,bo)`` per-mode, ``(bb,)+rev_modes+(bo)``
    shared, plus the bypass scratch ``(bo,bb)+spatial`` for the block
    epilogue).

The estimate is deliberately a floor (it ignores Mosaic's own padding of
sub-(8,128) tiles), so "over budget" findings are real. Severity policy
(since the autotuner landed): EVERY config — reduced and full-size — must
resolve plans that fit, at error severity. A full-size config erroring
here means the committed tuned cache (``tuning/cache/blocks.json``) lost
coverage for its shape class; regenerate with ``scripts/autotune.py``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp

from repro.analysis import Finding
from repro.configs.base import FNOConfig, PrecisionPolicy

# Per-core VMEM on current TPU generations (v4/v5e/v5p are all 16 MiB;
# interpret-mode CI has no such limit — the budget is about real TPUs).
VMEM_BUDGET_BYTES = 16 * 1024 * 1024


@dataclasses.dataclass(frozen=True)
class LaunchEstimate:
    """Bytes resident in VMEM for one engine launch (one grid program)."""

    launch: str        # block_fwd | gz_recompute | dx_adjoint | wgrad | core
    operand_bytes: int  # double-buffered windows + single-buffered mats
    scratch_bytes: int  # declared VMEM accumulators

    @property
    def total_bytes(self) -> int:
        return self.operand_bytes + self.scratch_bytes


def _isz(dtype: str) -> int:
    return jnp.dtype(dtype).itemsize


def _prod(xs: Sequence[int]) -> int:
    return int(math.prod(xs))


def _mats_bytes(mats) -> int:
    return sum(int(m.size) * m.dtype.itemsize for m in mats)


def resolve_blocks(rank: int, b: int, h: int, o: int,
                   bb: int = 0, bo: int = 0, bh: int = 0
                   ) -> Tuple[int, int, int]:
    """The (bb, bo, bh) the ops layer would pick: per-rank defaults from
    the block-size table, shrunk to the (8-aligned) actual dims."""
    from repro.kernels.ops import _pick_block, _resolve_blocks
    bb, bo, bh = _resolve_blocks(rank, bb, bo, bh)
    return _pick_block(b, bb), _pick_block(o, bo), _pick_block(h, bh)


def _rev_modes(modes: Sequence[int]) -> Tuple[int, ...]:
    """Accumulator-order spectral extents, with the rank-1 lane-alignment
    pad (``ops._mode_pad``) applied."""
    from repro.kernels.ops import _mode_pad
    kp = _mode_pad(modes)
    return (kp,) if len(modes) == 1 else tuple(reversed(modes))


def _fused_call_estimate(launch: str, spatial, modes, bb, bo, bh, per_mode,
                         pol: PrecisionPolicy, *, with_epilogue: bool,
                         with_gy: bool, out_dtype: Optional[str] = None,
                         adjoint: bool = False) -> LaunchEstimate:
    """One ``engine.fused_fnond_call`` program, block epilogue included."""
    from repro.core import spectral
    from repro.kernels.ops import _mode_pad

    r = len(modes)
    cb = _isz(pol.compute_dtype)
    ab = _isz(pol.accum_dtype)
    ob = _isz(out_dtype or pol.compute_dtype)
    sp = _prod(spatial)
    kp = _mode_pad(modes)
    rev = _rev_modes(modes)
    mats = spectral.fused_operand_mats(tuple(spatial), tuple(modes),
                                       pol.spectral_dtype, adjoint, kp)
    wmodes = _prod((kp,) if r == 1 else tuple(modes)) if per_mode else 1

    operands = 2 * (bb * bh * sp * cb)                 # x window
    operands += 2 * (2 * bo * bh * wmodes * cb)        # wr + wi windows
    operands += _mats_bytes(mats)                      # constant-index mats
    operands += 2 * (bb * bo * sp * ob)                # y window
    if with_epilogue:
        operands += 2 * (bo * bh * cb)                 # wb window
        if not adjoint:
            operands += 2 * (bo * 1 * cb)              # bias window
    if with_gy:
        operands += 2 * (bb * bo * sp * cb)            # gy window

    acc = _prod(rev) * bb * bo * ab
    scratch = 2 * acc                                  # accr + acci
    if with_epilogue:
        scratch += bo * bb * sp * ab                   # bypass accumulator
    return LaunchEstimate(launch, operands, scratch)


def _core_call_estimate(spatial, modes, bb, bo, bh, per_mode,
                        pol: PrecisionPolicy) -> LaunchEstimate:
    """One partial-fusion middle program (``fused_fnond_core_call``)."""
    from repro.core import spectral

    r = len(modes)
    cb = _isz(pol.spectral_dtype)
    ab = _isz(pol.accum_dtype)
    nx = spatial[0]
    spec = tuple(reversed(modes[1:]))  # K_R .. K_2
    mats = spectral.fused_operand_mats(tuple(spatial), tuple(modes),
                                       pol.spectral_dtype)
    fr = mats[2 * r - 2]
    kx = int(fr.shape[1])
    core_mats = mats[2 * r - 2:2 * r + 2]
    wmodes = _prod(modes) if per_mode else 1

    z_elems = bb * bh * nx * _prod(spec)
    y_elems = bb * bo * nx * _prod(spec)
    operands = 2 * (2 * z_elems * cb)                  # zr + zi windows
    operands += 2 * (2 * bo * bh * wmodes * cb)        # wr + wi windows
    operands += _mats_bytes(core_mats)                 # f/g operand pairs
    operands += 2 * (2 * y_elems * cb)                 # yr + yi windows
    scratch = 2 * (_prod(spec) * kx * bb * bo * ab)
    return LaunchEstimate("core", operands, scratch)


def _wgrad_estimate(spatial, modes, bb, bo, bh, per_mode,
                    pol: PrecisionPolicy, *,
                    with_bypass: bool) -> LaunchEstimate:
    """One fused weight-gradient program (``fused_fnond_wgrad_call``)."""
    from repro.core import spectral
    from repro.kernels.ops import _mode_pad

    cb = _isz(pol.compute_dtype)
    ab = _isz(pol.accum_dtype)
    pb = _isz(pol.param_dtype)
    sp = _prod(spatial)
    kp = _mode_pad(modes)
    rev = _rev_modes(modes)
    mats = spectral.wgrad_operand_mats(tuple(spatial), tuple(modes),
                                       pol.spectral_dtype, kp)
    dw_elems = (_prod(rev) if per_mode else 1) * bo * bh

    operands = 2 * (bb * bh * sp * cb)                 # x window
    operands += 2 * (bb * bo * sp * cb)                # gz window
    operands += _mats_bytes(mats)
    operands += 2 * (2 * dw_elems * pb)                # dwr + dwi windows
    if with_bypass:
        operands += 2 * ((bo * bh + bo) * pb)          # dwb + dbias windows
    scratch = 2 * (dw_elems * ab)
    if with_bypass:
        scratch += (bo * bh + bo) * ab
    return LaunchEstimate("wgrad", operands, scratch)


def _rup8(v: int) -> int:
    return -(-int(v) // 8) * 8


def ends_launch_estimate(cfg: FNOConfig, *, batch: int = 8,
                         policy: Optional[PrecisionPolicy] = None,
                         plans=None) -> LaunchEstimate:
    """The ends-fused forward launch — ``engine.fused_fnond_call`` with
    the lifting MLP folded in as a k==0 prologue and the projection MLP
    as an output epilogue (``cfg.fuse_ends``). Models the worst case
    (both ends on one launch — the 1-layer shape; a lift-only first
    layer or proj-only last layer is strictly smaller). Differences vs
    ``block_fwd``:

      * the x window carries raw ``in_channels`` (8-padded) instead of
        hidden, and the y window carries 8-padded ``out_channels``;
      * bo is PINNED to the 8-padded hidden — the projection epilogue
        contracts the full post-activation hidden vector, so the o-grid
        collapses to one step;
      * the l2 lift window rides the k-grid (double-buffered ×2); the l1
        and projection operands use constant index maps (×1);
      * one extra scratch buffer: the lifted activation ``acca``
        [lift_p, bb, *spatial] persisting across the k-loop. This term
        scales with lift×spatial and dominates at full size (fno2d at
        bb=1 still pays 12.5 MiB of scratch; fno3d's 64³ grid needs
        129 MiB and does NOT fit) — fuse_ends is a small-spatial-extent
        optimisation until the lift prologue learns to spatial-block,
        which is why no full-size preset enables it.

    Backward adds no launches: the ends-fused block's VJP re-stages the
    composition, so this forward launch is the only one the flag adds.
    ``check_vmem`` includes it (via ``block_launch_estimates``) exactly
    when the config opts into fuse_ends."""
    from repro import tuning
    from repro.core import spectral
    from repro.kernels.ops import _mode_pad, _pick_block

    h, spatial, modes, per_mode, pol = _norm_shapes(cfg, policy)
    r = len(modes)
    if plans is None:
        plans = tuning.resolve_launch_plans(
            r, hidden=h, spatial=spatial, modes=modes, per_mode=per_mode,
            policy=pol, override=cfg.block_plan)
    pbb, _, pbh = plans.for_launch("block_fwd")
    bb = _pick_block(batch, pbb)
    bh = _pick_block(h, pbh)
    op_ = _rup8(h)                        # bo pinned: single o-grid step
    cinp = _rup8(cfg.in_channels)
    lp = _rup8(cfg.lifting_dim or 2 * h)
    coutp = _rup8(cfg.out_channels)

    cb = _isz(pol.compute_dtype)
    ab = _isz(pol.accum_dtype)
    sp = _prod(spatial)
    kp = _mode_pad(modes)
    rev = _rev_modes(modes)
    mats = spectral.fused_operand_mats(tuple(spatial), tuple(modes),
                                       pol.spectral_dtype, False, kp)
    wmodes = _prod((kp,) if r == 1 else tuple(modes)) if per_mode else 1

    operands = 2 * (bb * cinp * sp * cb)               # raw-x window
    operands += 2 * (2 * op_ * bh * wmodes * cb)       # wr + wi windows
    operands += _mats_bytes(mats)                      # constant-index mats
    operands += 2 * (bb * coutp * sp * cb)             # y window
    operands += 2 * (op_ * bh * cb) + 2 * (op_ * cb)   # wb + bias windows
    operands += (lp * cinp + lp) * cb                  # l1w/l1b (constant)
    operands += 2 * (bh * lp * cb) + 2 * (bh * cb)     # l2w/l2b (k-grid)
    operands += (lp * op_ + lp + coutp * lp + coutp) * cb  # proj (constant)

    scratch = 2 * (_prod(rev) * bb * op_ * ab)         # accr + acci
    scratch += op_ * bb * sp * ab                      # bypass accumulator
    scratch += lp * bb * sp * ab                       # acca (lift prologue)
    return LaunchEstimate("block_fwd_ends", operands, scratch)


def _norm_shapes(cfg_or_shapes, policy):
    """(hidden, spatial, modes, per_mode, policy) from an FNOConfig or a
    ``(hidden, spatial, modes, per_mode)`` tuple."""
    if isinstance(cfg_or_shapes, FNOConfig):
        cfg = cfg_or_shapes
        return (cfg.hidden, tuple(cfg.spatial), tuple(cfg.modes),
                cfg.weight_mode == "per_mode", policy or cfg.precision)
    h, spatial, modes, per_mode = cfg_or_shapes
    return (int(h), tuple(spatial), tuple(modes), bool(per_mode),
            policy or PrecisionPolicy())


def launch_estimate(cfg_or_shapes, launch: str,
                    triple: Tuple[int, int, int], *, batch: int = 8,
                    policy: Optional[PrecisionPolicy] = None
                    ) -> LaunchEstimate:
    """Estimate ONE launch kind under an explicit (bb, bo, bh) block
    preference — the autotuner's pruning oracle and the cache staleness
    re-check. The preference is clamped to the actual dims exactly like
    the ops layer does at call time (``ops._pick_block``). dx_adjoint
    runs with hidden/out swapped in the real kernel; o == h throughout
    this repo's FNO stacks, so the unswapped estimate is exact."""
    from repro.kernels.ops import _pick_block

    h, spatial, modes, per_mode, pol = _norm_shapes(cfg_or_shapes, policy)
    o = h
    bb = _pick_block(batch, triple[0])
    bo = _pick_block(o, triple[1])
    bh = _pick_block(h, triple[2])
    if launch == "core":
        return _core_call_estimate(spatial, modes, bb, bo, bh, per_mode,
                                   pol)
    if launch == "wgrad":
        return _wgrad_estimate(spatial, modes, bb, bo, bh, per_mode, pol,
                               with_bypass=True)
    if launch == "block_fwd":
        return _fused_call_estimate(
            "block_fwd", spatial, modes, bb, bo, bh, per_mode, pol,
            with_epilogue=True, with_gy=False)
    if launch == "gz_recompute":
        return _fused_call_estimate(
            "gz_recompute", spatial, modes, bb, bo, bh, per_mode, pol,
            with_epilogue=True, with_gy=True)
    if launch == "dx_adjoint":
        return _fused_call_estimate(
            "dx_adjoint", spatial, modes, bb, bo, bh, per_mode, pol,
            with_epilogue=True, with_gy=False, adjoint=True)
    raise ValueError(f"unknown launch kind {launch!r}")


def block_launch_estimates(cfg_or_shapes, *, variant: str = "full",
                           batch: int = 8,
                           policy: Optional[PrecisionPolicy] = None,
                           plans=None) -> Dict[str, LaunchEstimate]:
    """Per-launch VMEM estimates for one fused FNO block's full training
    step (forward + the three backward kernels).

    Accepts an ``FNOConfig`` (hidden/modes/spatial/weight_mode read off
    it) or a ``(hidden, spatial, modes, per_mode)`` tuple. ``plans``
    (a ``tuning.LaunchPlans``) pins the block preferences explicitly;
    None resolves them the same way the ops layer will at call time —
    tuned cache first, static defaults as fallback.
    """
    from repro import tuning

    h, spatial, modes, per_mode, pol = _norm_shapes(cfg_or_shapes, policy)
    r = len(modes)
    if plans is None:
        override = (cfg_or_shapes.block_plan
                    if isinstance(cfg_or_shapes, FNOConfig) else None)
        plans = tuning.resolve_launch_plans(
            r, hidden=h, spatial=spatial, modes=modes, per_mode=per_mode,
            policy=pol, override=override)
    shapes = (h, spatial, modes, per_mode)
    one = lambda launch: launch_estimate(shapes, launch,
                                         plans.for_launch(launch),
                                         batch=batch, policy=pol)
    full = variant == "full" or r == 1

    est: Dict[str, LaunchEstimate] = {}
    if full:
        est["block_fwd"] = one("block_fwd")
    else:
        est["core"] = one("core")
    if isinstance(cfg_or_shapes, FNOConfig) and cfg_or_shapes.fuse_ends:
        # The ends-fused first/last-layer launch (worst case: both ends).
        est["block_fwd_ends"] = ends_launch_estimate(
            cfg_or_shapes, batch=batch, policy=pol, plans=plans)
    # Backward is always the fully fused adjoint (one linear map serves
    # both variants — ops._fno_block_vjp_bwd).
    est["gz_recompute"] = one("gz_recompute")
    est["dx_adjoint"] = one("dx_adjoint")
    est["wgrad"] = one("wgrad")
    return est


def check_vmem(configs=None, dtypes: Sequence[str] = ("f32", "bf16"),
               variants: Sequence[str] = ("full", "partial"),
               budget: int = VMEM_BUDGET_BYTES) -> List[Finding]:
    """Estimate every engine launch of the given configs against the VMEM
    budget, at the plans the ops layer would actually resolve (tuned
    cache → defaults). configs: FNOConfigs or legacy (cfg, must_fit)
    pairs — every config must fit now (error severity): since the
    autotuner landed, a full-size config over budget means the committed
    cache lost coverage, not an accepted limitation. Defaults to all FNO
    archs at reduced AND full size."""
    from repro.configs import FNO_IDS, get_config

    if configs is None:
        configs = [get_config(a, reduced=True) for a in FNO_IDS]
        configs += [get_config(a, reduced=False) for a in FNO_IDS]

    findings: List[Finding] = []
    for entry in configs:
        cfg = entry[0] if isinstance(entry, tuple) else entry
        for dtype in dtypes:
            pol = PrecisionPolicy.from_name(dtype)
            for variant in variants:
                ests = block_launch_estimates(cfg, variant=variant,
                                              policy=pol)
                for name, e in ests.items():
                    if e.total_bytes <= budget:
                        continue
                    findings.append(Finding(
                        "vmem-budget",
                        f"{cfg.name}/{variant}/{dtype}/{name}",
                        f"estimated {e.total_bytes / 2**20:.1f} MiB VMEM "
                        f"per program ({e.operand_bytes / 2**20:.1f} operand"
                        f" + {e.scratch_bytes / 2**20:.1f} scratch) exceeds "
                        f"the {budget / 2**20:.0f} MiB budget — no tuned "
                        f"plan covers this shape class; regenerate the "
                        f"cache (scripts/autotune.py) or shrink (bb,bo,bh)"))
    return findings
