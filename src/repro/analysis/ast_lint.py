"""Source-level contract lints (AST rules) + the config-registry audit.

These encode the repo's compat and precision policies as mechanical rules
over ``src/repro`` (ROADMAP.md §Durable design contracts, DESIGN.md §7):

  * **pallas-compiler-params** — every ``pl.pallas_call`` must pass
    ``compiler_params=_compiler_params(...)``: the one shim that resolves
    the TPUCompilerParams/CompilerParams rename across JAX versions. A raw
    pallas_call breaks on one side of the support matrix.
  * **compat-shard-map** — ``jax.experimental.shard_map`` may only be
    imported inside ``distributed/sharding.py`` (home of
    ``compat_shard_map``, which resolves the check_rep→check_vma rename).
  * **no-raw-fft** — ``jnp.fft`` is the oracle's tool (``kernels/ref.py``)
    and the data generator's (``data/pde.py``); production paths must use
    the truncated-DFT formulation (``core/spectral.py`` operands through
    the kernels), where truncation is free and fusion is possible.
  * **dtype-literal** — inside the precision-policy-governed files, float
    dtype literals (``jnp.float32`` & co) may appear only at the
    allowlisted cast-ownership boundaries (DESIGN.md §4); everywhere else
    the dtype must come from the ``PrecisionPolicy``. Annotate a
    legitimate new boundary with ``# lint: allow-dtype`` (and say why in
    DESIGN.md §4).

``check_config_registry`` closes the configs audit: every seeded arch
must be enumerated by ``configs.runnable_cells()`` with, per cell, either
runnability or a non-empty skip reason — and at least one runnable cell.

This module imports no jax: it runs anywhere, first, fast.
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.analysis import Finding

# Where each policy legitimately lives (paths relative to src/repro).
SHARD_MAP_HOME = "distributed/sharding.py"
FFT_ALLOWED = ("kernels/ref.py", "data/pde.py")

# Files under the PrecisionPolicy contract, with the owner functions
# allowed to hold float-dtype literals ("<module>" = module level). These
# are exactly the cast-ownership boundaries of DESIGN.md §4.
DTYPE_SCOPE: Dict[str, Tuple[str, ...]] = {
    "kernels/engine.py": ("<module>",),        # _F32 accumulator default
    "kernels/cgemm.py": ("<module>",),         # _F32 accumulator default
    "kernels/dft.py": ("<module>",),           # _F32 accumulator default
    "kernels/ops.py": ("_spectral_layer_nd",   # f32 oracle boundary
                       "_block_tail"),         # f32 epilogue accumulation
    "core/fno.py": ("_dense_init",             # f32 master-param init
                    "relative_l2"),            # f32 metric reduction
    "core/spectral_conv.py": ("init_spectral_nd", "init_spectral_1d",
                              "init_spectral_2d", "init_spectral_3d"),
    "train/train_step.py": ("make_train_step",  # f32 grad-acc fallback
                            "train_step"),      # f32 loss accumulator
}
DTYPE_ATTRS = frozenset({"float32", "float64", "float16", "bfloat16"})
DTYPE_PRAGMA = "lint: allow-dtype"


def repo_src_root() -> Path:
    return Path(__file__).resolve().parents[1]


def _call_name(call: ast.Call) -> str:
    f = call.func
    if isinstance(f, ast.Attribute):
        return f.attr
    if isinstance(f, ast.Name):
        return f.id
    return ""


class _Visitor(ast.NodeVisitor):
    def __init__(self, rel: str, lines: Sequence[str]):
        self.rel = rel
        self.lines = lines
        self.owners = ["<module>"]
        self.findings: List[Finding] = []

    # -- owner tracking ------------------------------------------------
    def visit_FunctionDef(self, node):
        self.owners.append(node.name)
        self.generic_visit(node)
        self.owners.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def _where(self, node) -> str:
        return f"{self.rel}:{node.lineno}"

    def _line_has_pragma(self, node) -> bool:
        line = self.lines[node.lineno - 1] if node.lineno <= len(
            self.lines) else ""
        return DTYPE_PRAGMA in line

    # -- rules ---------------------------------------------------------
    def visit_Call(self, node: ast.Call):
        if _call_name(node) == "pallas_call":
            cp = next((kw.value for kw in node.keywords
                       if kw.arg == "compiler_params"), None)
            ok = (isinstance(cp, ast.Call)
                  and _call_name(cp).endswith("_compiler_params"))
            if not ok:
                self.findings.append(Finding(
                    "pallas-compiler-params", self._where(node),
                    "pl.pallas_call without compiler_params="
                    "_compiler_params(...) — pass dimension semantics "
                    "through the kernels/__init__ shim so the call "
                    "survives the TPUCompilerParams/CompilerParams rename "
                    "(ROADMAP §JAX version compat)"))
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom):
        mod = node.module or ""
        names = [a.name for a in node.names]
        if ("shard_map" in mod or "shard_map" in names) \
                and self.rel != SHARD_MAP_HOME:
            self.findings.append(Finding(
                "compat-shard-map", self._where(node),
                "raw shard_map import — use distributed.sharding."
                "compat_shard_map, the one shim that spans the "
                "check_rep→check_vma rename across JAX versions"))
        self.generic_visit(node)

    def visit_Import(self, node: ast.Import):
        if any("shard_map" in a.name for a in node.names) \
                and self.rel != SHARD_MAP_HOME:
            self.findings.append(Finding(
                "compat-shard-map", self._where(node),
                "raw shard_map import — use distributed.sharding."
                "compat_shard_map"))
        self.generic_visit(node)

    def visit_Attribute(self, node: ast.Attribute):
        if node.attr == "fft" and self.rel not in FFT_ALLOWED:
            self.findings.append(Finding(
                "no-raw-fft", self._where(node),
                "jnp.fft on a production path — the kernels consume the "
                "truncated-DFT operand formulation (core/spectral.py); "
                "jnp.fft belongs only to the oracle (kernels/ref.py) and "
                "the data generators (data/pde.py)"))
        if (node.attr in DTYPE_ATTRS
                and isinstance(node.value, ast.Name)
                and node.value.id in ("jnp", "np", "numpy")
                and self.rel in DTYPE_SCOPE
                and self.owners[-1] not in DTYPE_SCOPE[self.rel]
                and not self._line_has_pragma(node)):
            self.findings.append(Finding(
                "dtype-literal", self._where(node),
                f"dtype literal {node.value.id}.{node.attr} outside the "
                f"allowlisted cast-ownership boundaries of {self.rel} "
                f"(owner {self.owners[-1]!r}) — take the dtype from the "
                f"PrecisionPolicy, or annotate a legitimate new boundary "
                f"with '# {DTYPE_PRAGMA}' and document it in DESIGN.md §4"))
        self.generic_visit(node)


def lint_file(path: Path, root: Optional[Path] = None) -> List[Finding]:
    root = root or repo_src_root()
    rel = path.resolve().relative_to(root.resolve()).as_posix()
    src = path.read_text()
    try:
        tree = ast.parse(src, filename=str(path))
    except SyntaxError as e:
        return [Finding("ast-parse", f"{rel}:{e.lineno}",
                        f"file does not parse: {e.msg}")]
    v = _Visitor(rel, src.splitlines())
    v.visit(tree)
    return v.findings


def run_ast_lints(root: Optional[Path] = None,
                  files: Optional[Iterable[Path]] = None) -> List[Finding]:
    """Lint every .py file under `root` (default: src/repro)."""
    root = root or repo_src_root()
    if files is None:
        files = sorted(p for p in root.rglob("*.py")
                       if "__pycache__" not in p.parts)
    findings: List[Finding] = []
    for path in files:
        findings += lint_file(path, root)
    return findings


def check_config_registry() -> List[Finding]:
    """Every seeded arch builds at least one runnable cell, and every
    skipped cell carries a non-empty reason (the carried-forward
    configs.skip_reason audit)."""
    from repro.configs import ALL_IDS, runnable_cells

    findings: List[Finding] = []
    cells = list(runnable_cells())
    by_arch: Dict[str, List] = {}
    for arch, shape, reason in cells:
        by_arch.setdefault(arch, []).append((shape, reason))
        if reason is not None and not str(reason).strip():
            findings.append(Finding(
                "config-registry", f"{arch}/{shape}",
                "cell is skipped with an EMPTY reason — state why or make "
                "it runnable"))
    for arch in ALL_IDS:
        rows = by_arch.get(arch)
        if not rows:
            findings.append(Finding(
                "config-registry", arch,
                "seeded arch is never enumerated by "
                "configs.runnable_cells() — it can silently rot; add it "
                "to the cell grid or remove the config"))
        elif not any(reason is None for _, reason in rows):
            findings.append(Finding(
                "config-registry", arch,
                "arch has no runnable cell at all (every shape skipped) — "
                "a config nothing can ever run is dead weight"))
    return findings
