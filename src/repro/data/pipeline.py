"""Prefetching, straggler-tolerant data pipeline.

A background thread produces batches ahead of the training loop (depth-k
prefetch). ``get(timeout)`` implements straggler mitigation at the data
layer: if a batch is not ready in time, the iterator SKIPS to the next index
(permissible because batches are stateless functions of their index) and
records the skip — the training loop never stalls on a slow producer.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Callable, Dict, Iterator, Optional


class PrefetchPipeline:
    def __init__(self, batch_fn: Callable[[int], Dict], start_index: int = 0,
                 depth: int = 2):
        self.batch_fn = batch_fn
        self.depth = depth
        self.next_index = start_index
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.skipped = 0
        self._failed_at: Optional[int] = None  # producer death is terminal
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        idx = self.next_index
        while not self._stop.is_set():
            try:
                batch = self.batch_fn(idx)
            except Exception:  # propagate as sentinel
                self._q.put((idx, None))
                return
            self._q.put((idx, batch))
            idx += 1

    def get(self, timeout: Optional[float] = None):
        """Next (index, batch). On timeout, counts a skip and retries —
        the loop keeps moving past a straggling producer.

        ``timeout`` is passed through verbatim: None blocks, an explicit
        0 polls (a zero-second timeout is a timeout, not "no timeout").
        Producer death is TERMINAL: once the failure sentinel has been
        consumed, every subsequent ``get`` raises immediately instead of
        spinning on an empty queue counting skips forever."""
        while True:
            if self._failed_at is not None:
                raise RuntimeError(
                    f"data producer failed at index {self._failed_at}")
            try:
                idx, batch = self._q.get(timeout=timeout)
            except queue.Empty:
                if not self._thread.is_alive() and self._q.empty():
                    raise RuntimeError(
                        "data producer is dead and the queue is drained")
                self.skipped += 1
                continue
            if batch is None:
                self._failed_at = idx
                raise RuntimeError(f"data producer failed at index {idx}")
            return idx, batch

    def stop(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
