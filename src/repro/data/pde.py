"""Synthetic PDE data generation in JAX (the FNO training substrate).

* Burgers 1D:  u_t + u·u_x = ν·u_xx, periodic, spectral RK4 integrator.
  Sample (u₀ GRF) → (u₀, u(T)) pairs — the classic FNO-1D benchmark task.
* Darcy 2D:   -∇·(a(x)∇u) = f on the unit square, u=0 on ∂Ω; piecewise-
  constant a from a thresholded GRF; solved with Jacobi-preconditioned CG
  on a finite-difference stencil (pure jnp, fixed iteration count).
* Diffusion 3D: u_t = ν·Δu + r·u on the periodic unit cube, solved exactly
  in spectral space (each Fourier mode decays as exp((r − ν|2πk|²)T)) —
  the rank-3 operator-learning substrate for FNO3d without a costly
  time-stepper.

Everything is stateless and seeded: batch i of a run is a pure function of
(seed, i), so any host can regenerate any shard after failover
(docs/DESIGN.md §6 fault tolerance).
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Gaussian random fields (periodic, power-law spectrum)
# ---------------------------------------------------------------------------
def grf_1d(key, batch: int, n: int, alpha: float = 2.5, tau: float = 7.0
           ) -> jax.Array:
    k = jnp.fft.rfftfreq(n, 1.0 / n)
    spec = (k ** 2 + tau ** 2) ** (-alpha / 2.0)
    kr, ki = jax.random.split(key)
    re = jax.random.normal(kr, (batch, k.shape[0]))
    im = jax.random.normal(ki, (batch, k.shape[0]))
    coef = (re + 1j * im) * spec * n
    return jnp.fft.irfft(coef, n=n, axis=-1)


def grf_2d(key, batch: int, n: int, alpha: float = 2.0, tau: float = 3.0
           ) -> jax.Array:
    kx = jnp.fft.fftfreq(n, 1.0 / n)
    ky = jnp.fft.rfftfreq(n, 1.0 / n)
    k2 = kx[:, None] ** 2 + ky[None, :] ** 2
    spec = (k2 + tau ** 2) ** (-alpha / 2.0)
    kr, ki = jax.random.split(key)
    re = jax.random.normal(kr, (batch, n, ky.shape[0]))
    im = jax.random.normal(ki, (batch, n, ky.shape[0]))
    coef = (re + 1j * im) * spec * n
    return jnp.fft.irfft2(coef, s=(n, n), axes=(-2, -1))


def _k2_grid_3d(n: int) -> jax.Array:
    """|k|² over the rfftn layout [n, n, n//2+1] (integer wavenumbers)."""
    kf = jnp.fft.fftfreq(n, 1.0 / n)
    kr = jnp.fft.rfftfreq(n, 1.0 / n)
    return (kf[:, None, None] ** 2 + kf[None, :, None] ** 2
            + kr[None, None, :] ** 2)


def grf_3d(key, batch: int, n: int, alpha: float = 2.5, tau: float = 3.0
           ) -> jax.Array:
    k2 = _k2_grid_3d(n)
    spec = (k2 + tau ** 2) ** (-alpha / 2.0)
    kr, ki = jax.random.split(key)
    shape = (batch,) + k2.shape
    coef = ((jax.random.normal(kr, shape) + 1j * jax.random.normal(ki, shape))
            * spec * n ** 1.5)
    return jnp.fft.irfftn(coef, s=(n, n, n), axes=(-3, -2, -1))


# ---------------------------------------------------------------------------
# Burgers 1D
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n", "steps"))
def burgers_solve(u0: jax.Array, *, nu: float = 0.01, t_final: float = 1.0,
                  n: int = 256, steps: int = 200) -> jax.Array:
    """Spectral RK4 for periodic Burgers. u0: [B, n] -> u(T): [B, n]."""
    dt = t_final / steps
    k = 2j * jnp.pi * jnp.fft.rfftfreq(n, 1.0 / n)
    dealias = jnp.abs(jnp.fft.rfftfreq(n, 1.0 / n)) < (n // 3)

    def rhs(uh):
        u = jnp.fft.irfft(uh, n=n, axis=-1)
        conv = jnp.fft.rfft(0.5 * u * u, axis=-1) * dealias
        return -k * conv + nu * k ** 2 * uh

    def step(uh, _):
        k1 = rhs(uh)
        k2 = rhs(uh + 0.5 * dt * k1)
        k3 = rhs(uh + 0.5 * dt * k2)
        k4 = rhs(uh + dt * k3)
        return uh + dt / 6 * (k1 + 2 * k2 + 2 * k3 + k4), None

    uh0 = jnp.fft.rfft(u0, axis=-1)
    uhT, _ = jax.lax.scan(step, uh0, None, length=steps)
    return jnp.fft.irfft(uhT, n=n, axis=-1)


def burgers_batch(seed: int, index: int, batch: int, n: int = 256,
                  nu: float = 0.01) -> Dict[str, jax.Array]:
    """Deterministic batch `index` of a run: x=[B,1,n] u0, y=[B,1,n] u(T)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed), index)
    u0 = grf_1d(key, batch, n)
    u0 = u0 / (jnp.std(u0, axis=-1, keepdims=True) + 1e-6)
    uT = burgers_solve(u0, nu=nu, n=n)
    return {"x": u0[:, None, :].astype(jnp.float32),
            "y": uT[:, None, :].astype(jnp.float32)}


# ---------------------------------------------------------------------------
# Diffusion-reaction 3D (periodic cube, exact spectral propagator)
# ---------------------------------------------------------------------------
@functools.partial(jax.jit, static_argnames=("n",))
def diffusion3d_solve(u0: jax.Array, *, nu: float = 0.05, r: float = 1.0,
                      t_final: float = 0.25, n: int = 16) -> jax.Array:
    """u_t = ν·Δu + r·u on the periodic unit cube — exact in Fourier space.

    u0: [B, n, n, n] -> u(T): [B, n, n, n]. Each mode k evolves as
    exp((r − ν·|2πk|²)·T): low modes grow (reaction), high modes decay
    (diffusion) — a non-trivial but analytically exact operator target.
    """
    decay = jnp.exp((r - nu * (2.0 * jnp.pi) ** 2 * _k2_grid_3d(n))
                    * t_final)
    uh = jnp.fft.rfftn(u0, axes=(-3, -2, -1))
    return jnp.fft.irfftn(uh * decay, s=(n, n, n), axes=(-3, -2, -1))


def diffusion3d_batch(seed: int, index: int, batch: int, n: int = 16,
                      nu: float = 0.05) -> Dict[str, jax.Array]:
    """Deterministic batch `index`: x = [B,1,n,n,n] u0, y = [B,1,n,n,n]
    u(T). Stateless-seeded like the 1D/2D tasks (failover-regenerable)."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 333), index)
    u0 = grf_3d(key, batch, n)
    u0 = u0 / (jnp.std(u0.reshape(batch, -1), axis=-1)
               .reshape(batch, 1, 1, 1) + 1e-6)
    uT = diffusion3d_solve(u0, nu=nu, n=n)
    return {"x": u0[:, None].astype(jnp.float32),
            "y": uT[:, None].astype(jnp.float32)}


# ---------------------------------------------------------------------------
# Darcy 2D
# ---------------------------------------------------------------------------
def _darcy_apply(a: jax.Array, u: jax.Array, h: float) -> jax.Array:
    """-∇·(a∇u) with a 5-point harmonic-mean stencil; u=0 boundary."""
    up = jnp.pad(u, ((0, 0), (1, 1), (1, 1)))
    ap = jnp.pad(a, ((0, 0), (1, 1), (1, 1)), mode="edge")
    hm = lambda x, y: 2 * x * y / (x + y + 1e-12)
    ae = hm(ap[:, 1:-1, 1:-1], ap[:, 1:-1, 2:])
    aw = hm(ap[:, 1:-1, 1:-1], ap[:, 1:-1, :-2])
    an = hm(ap[:, 1:-1, 1:-1], ap[:, 2:, 1:-1])
    as_ = hm(ap[:, 1:-1, 1:-1], ap[:, :-2, 1:-1])
    flux = (ae * (up[:, 1:-1, 2:] - u) + aw * (up[:, 1:-1, :-2] - u)
            + an * (up[:, 2:, 1:-1] - u) + as_ * (up[:, :-2, 1:-1] - u))
    return -flux / h ** 2


@functools.partial(jax.jit, static_argnames=("iters",))
def darcy_solve(a: jax.Array, f: jax.Array, iters: int = 200) -> jax.Array:
    """CG for -∇·(a∇u)=f. a,f: [B, n, n] -> u: [B, n, n]."""
    n = a.shape[-1]
    h = 1.0 / (n + 1)
    dot = lambda p, q: jnp.sum(p * q, axis=(-2, -1), keepdims=True)

    def amul(u):
        return _darcy_apply(a, u, h)

    x = jnp.zeros_like(f)
    r = f - amul(x)
    p = r
    rs = dot(r, r)

    def body(carry, _):
        x, r, p, rs = carry
        ap = amul(p)
        alpha = rs / (dot(p, ap) + 1e-30)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = dot(r, r)
        p = r + (rs_new / (rs + 1e-30)) * p
        return (x, r, p, rs_new), None

    (x, _, _, _), _ = jax.lax.scan(body, (x, r, p, rs), None, length=iters)
    return x


def darcy_batch(seed: int, index: int, batch: int, n: int = 64,
                iters: int = 200) -> Dict[str, jax.Array]:
    """x = [B, 3, n, n] (a, grid_x, grid_y); y = [B, 1, n, n] u."""
    key = jax.random.fold_in(jax.random.PRNGKey(seed + 77), index)
    g = grf_2d(key, batch, n)
    a = jnp.where(g > 0, 12.0, 3.0)
    f = jnp.ones((batch, n, n))
    u = darcy_solve(a, f, iters=iters)
    xs = jnp.linspace(0, 1, n)
    gx = jnp.broadcast_to(xs[None, :, None], (batch, n, n))
    gy = jnp.broadcast_to(xs[None, None, :], (batch, n, n))
    x = jnp.stack([a / 10.0, gx, gy], axis=1)
    scale = 1.0 / (jnp.std(u) + 1e-9)
    return {"x": x.astype(jnp.float32),
            "y": (u * scale)[:, None].astype(jnp.float32)}
