"""Synthetic LM token pipeline: deterministic, stateless, host-shardable.

Batch `i` is a pure function of (seed, i, host_shard) — after a failover any
replacement host regenerates exactly its shard (no data-loader state in the
checkpoint beyond the step counter). The generator mimics Zipfian token
statistics so losses move like real text rather than uniform noise.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def zipf_logits(vocab: int) -> np.ndarray:
    ranks = np.arange(1, vocab + 1)
    return (-1.1 * np.log(ranks)).astype(np.float32)


def token_batch(seed: int, index: int, batch: int, seq_len: int, vocab: int,
                shard: int = 0, num_shards: int = 1) -> Dict[str, jnp.ndarray]:
    """Returns {"tokens": [b, S], "labels": [b, S]} for this host's shard."""
    assert batch % num_shards == 0
    b = batch // num_shards
    key = jax.random.fold_in(
        jax.random.fold_in(jax.random.PRNGKey(seed), index), shard)
    logits = jnp.asarray(zipf_logits(vocab))
    toks = jax.random.categorical(
        key, jnp.broadcast_to(logits, (b, seq_len + 1, vocab)))
    toks = toks.astype(jnp.int32)
    return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
