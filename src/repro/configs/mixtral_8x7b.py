"""Mixtral-8x7B — MoE decoder: 8 experts top-2, sliding-window attention.

[arXiv:2401.04088; hf]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "mixtral-8x7b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32000,
        attention="swa",
        window_size=4096,
        rope_style="full",
        rope_base=1_000_000.0,
        mlp="swiglu",
        norm="rmsnorm",
        num_experts=8,
        top_k=2,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, num_experts=4, top_k=2,
        window_size=16)
