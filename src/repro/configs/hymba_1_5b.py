"""Hymba-1.5B — hybrid-head decoder: attention and SSM heads in parallel
within every layer; SWA on most layers, 3 full-attention layers.

[arXiv:2411.13676; hf]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "hymba-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="hybrid",
        num_layers=32,
        d_model=1600,
        num_heads=25,
        num_kv_heads=5,
        head_dim=64,
        d_ff=5504,
        vocab_size=32001,
        attention="swa",
        window_size=1024,
        global_layers=(0, 15, 31),  # first / middle / last use full attention
        rope_style="full",
        rope_base=10000.0,
        mlp="swiglu",
        norm="rmsnorm",
        ssm_state=16,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        hybrid_parallel=True,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, window_size=16,
        global_layers=(0, 3), ssm_state=8, ssm_head_dim=16, ssm_chunk=16)
