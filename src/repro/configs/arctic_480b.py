"""Snowflake Arctic-480B — Dense-MoE hybrid: 128 experts top-2 with a dense
residual FFN in parallel. [hf:Snowflake/snowflake-arctic-base]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "arctic-480b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="moe",
        num_layers=35,
        d_model=7168,
        num_heads=56,
        num_kv_heads=8,
        head_dim=128,
        d_ff=4864,
        vocab_size=32000,
        attention="full",
        rope_style="full",
        rope_base=10000.0,
        mlp="swiglu",
        norm="rmsnorm",
        num_experts=128,
        top_k=2,
        dense_residual=True,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, num_experts=4, top_k=2)
