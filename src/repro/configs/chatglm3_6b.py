"""ChatGLM3-6B — dense GQA (multi-query groups=2), 2d/partial RoPE, QKV bias.

[arXiv:2406.12793; hf]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "chatglm3-6b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=28,
        d_model=4096,
        num_heads=32,
        num_kv_heads=2,
        head_dim=128,
        d_ff=13696,
        vocab_size=65024,
        attention="full",
        qkv_bias=True,
        rope_style="partial",  # ChatGLM rotates half of head_dim (2d RoPE)
        rope_fraction=0.5,
        rope_base=10000.0,
        mlp="swiglu",
        norm="rmsnorm",
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512)
