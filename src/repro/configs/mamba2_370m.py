"""Mamba2-370M — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060; unverified]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "mamba2-370m"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="ssm",
        num_layers=48,
        d_model=1024,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,  # Mamba2 blocks have no separate MLP
        vocab_size=50280,
        attention="none",
        rope_style="none",
        norm="rmsnorm",
        ssm_state=128,
        ssm_expand=2,
        ssm_head_dim=64,
        ssm_chunk=128,
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, ssm_state=16, ssm_head_dim=16,
        ssm_chunk=16, vocab_size=512)
