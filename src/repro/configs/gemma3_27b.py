"""Gemma3-27B — dense GQA, 5:1 local:global attention, 128k context.

[hf:google/gemma-3-*-pt; unverified]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "gemma3-27b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=62,
        d_model=5376,
        num_heads=32,
        num_kv_heads=16,
        head_dim=128,
        d_ff=21504,
        vocab_size=262144,
        attention="local_global",
        window_size=1024,
        local_per_global=5,
        rope_style="full",
        rope_base=1_000_000.0,
        mlp="geglu",
        norm="rmsnorm",
        tie_embeddings=True,
        logit_softcap=0.0,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, window_size=16)
