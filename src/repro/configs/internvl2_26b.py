"""InternVL2-26B — VLM: InternViT frontend (STUB) + InternLM2-20B backbone.

``input_specs()`` provides precomputed patch embeddings [B, P, d_model]
prepended to the token sequence. [arXiv:2404.16821; hf]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "internvl2-26b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="vlm",
        num_layers=48,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=92553,
        attention="full",
        rope_style="full",
        rope_base=1_000_000.0,
        mlp="swiglu",
        norm="rmsnorm",
        frontend="vision",
        num_prefix_embeds=256,  # IMG_CONTEXT tokens per tile
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512, num_prefix_embeds=8)
