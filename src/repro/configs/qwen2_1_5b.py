"""Qwen2-1.5B — dense GQA decoder with QKV bias. [arXiv:2407.10671; hf]"""
from repro.configs.base import ModelConfig

ARCH_ID = "qwen2-1.5b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=28,
        d_model=1536,
        num_heads=12,
        num_kv_heads=2,
        head_dim=128,
        d_ff=8960,
        vocab_size=151936,
        attention="full",
        qkv_bias=True,
        rope_style="full",
        rope_base=1_000_000.0,
        mlp="swiglu",
        norm="rmsnorm",
        tie_embeddings=True,
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=512)
