"""Architecture/shape registry.

``get_config("qwen2-1.5b")`` → full ModelConfig; ``get_config(id, reduced=True)``
→ CPU-smoke-sized variant of the same family. ``runnable_cells()`` enumerates
the (arch × shape) dry-run cells together with skip reasons (docs/DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Iterator, Optional, Tuple, Union

from repro.configs import (arctic_480b, chatglm3_6b, fno, gemma3_27b,
                           hubert_xlarge, hymba_1_5b, internvl2_26b,
                           mamba2_370m, mixtral_8x7b, nemotron_4_340b,
                           qwen2_1_5b)
from repro.configs.base import (SHAPES, SMOKE_SHAPES, FNOConfig, ModelConfig,
                                PrecisionPolicy, ShapeSpec)

_ARCH_MODULES = {
    "qwen2-1.5b": qwen2_1_5b,
    "gemma3-27b": gemma3_27b,
    "nemotron-4-340b": nemotron_4_340b,
    "chatglm3-6b": chatglm3_6b,
    "mamba2-370m": mamba2_370m,
    "hubert-xlarge": hubert_xlarge,
    "internvl2-26b": internvl2_26b,
    "mixtral-8x7b": mixtral_8x7b,
    "arctic-480b": arctic_480b,
    "hymba-1.5b": hymba_1_5b,
}

_FNO_FACTORIES = {
    "fno1d": (fno.fno1d, fno.reduced_1d),
    "fno2d": (fno.fno2d, fno.reduced_2d),
    "fno2d-large": (fno.fno2d_large, fno.reduced_2d),
    "fno3d": (fno.fno3d, fno.reduced_3d),
}

ARCH_IDS: Tuple[str, ...] = tuple(_ARCH_MODULES)
FNO_IDS: Tuple[str, ...] = tuple(_FNO_FACTORIES)
ALL_IDS: Tuple[str, ...] = ARCH_IDS + FNO_IDS


def get_config(arch: str, reduced: bool = False) -> Union[ModelConfig, FNOConfig]:
    if arch in _ARCH_MODULES:
        mod = _ARCH_MODULES[arch]
        cfg = mod.reduced() if reduced else mod.config()
        cfg.validate()
        return cfg
    if arch in _FNO_FACTORIES:
        full, red = _FNO_FACTORIES[arch]
        cfg = red() if reduced else full()
        cfg.validate()
        return cfg
    raise KeyError(f"unknown arch {arch!r}; known: {ALL_IDS}")


def get_shape(name: str, reduced: bool = False) -> ShapeSpec:
    table = SMOKE_SHAPES if reduced else SHAPES
    return table[name]


def skip_reason(arch: str, shape: str) -> Optional[str]:
    """Why an (arch × shape) cell is skipped, or None if runnable."""
    cfg = get_config(arch)
    if isinstance(cfg, FNOConfig):
        if shape in ("train_4k", "prefill_32k"):
            return None  # train cell / batched serving cell (ISSUE 5)
        return "FNO is a batch workload: no autoregressive decode shapes"
    if shape in ("decode_32k", "long_500k") and not cfg.is_decoder:
        return "encoder-only: no autoregressive decode step"
    if shape == "long_500k" and not cfg.sub_quadratic:
        return "pure full-attention: 500k context needs sub-quadratic attention"
    return None


def runnable_cells() -> Iterator[Tuple[str, str, Optional[str]]]:
    """Yield (arch, shape, skip_reason) for every seeded (arch × shape)
    cell — the 40 assigned LM cells AND the FNO archs (56 total), so no
    config can exist without either a runnable cell or a stated skip
    reason (the contract ``analysis.ast_lint.check_config_registry``
    enforces)."""
    for arch in ALL_IDS:
        for shape in SHAPES:
            yield arch, shape, skip_reason(arch, shape)
