"""Config dataclasses for models, FNO, shapes, and training runs.

Every assigned architecture is expressed as a single frozen ``ModelConfig``;
the unified transformer in ``repro.models.transformer`` interprets it. FNO
models (the paper's own architecture) use ``FNOConfig`` and are built by
``repro.core.fno``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Unified LM-family architecture description."""

    name: str
    family: str  # dense | moe | ssm | hybrid | encoder | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # --- attention ---
    attention: str = "full"  # full | swa | local_global | bidirectional | none
    window_size: int = 0  # for swa / local layers of local_global
    local_per_global: int = 0  # local_global: N local layers per global layer
    qkv_bias: bool = False
    logit_softcap: float = 0.0

    # --- positional encoding ---
    rope_style: str = "full"  # full | partial | none
    rope_fraction: float = 1.0  # fraction of head_dim rotated (partial/2d RoPE)
    rope_base: float = 10000.0

    # --- mlp / norm ---
    mlp: str = "swiglu"  # swiglu | geglu | gelu | relu2
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    tie_embeddings: bool = False

    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    dense_residual: bool = False  # Arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25

    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 128
    ssm_conv_width: int = 4

    # --- hybrid (Hymba) ---
    hybrid_parallel: bool = False  # attention and SSM heads in parallel per layer
    global_layers: Tuple[int, ...] = ()  # layer indices using full attention

    # --- modality frontend (stub: input_specs provides embeddings) ---
    frontend: str = "none"  # none | audio | vision
    num_prefix_embeds: int = 0  # VLM: patch embeddings prepended to tokens

    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    @property
    def d_attn(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def d_kv(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def is_decoder(self) -> bool:
        return self.attention != "bidirectional"

    @property
    def has_attention(self) -> bool:
        return self.attention != "none"

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def sub_quadratic(self) -> bool:
        """True when seq-len scaling is sub-quadratic (SSM / windowed attn)."""
        if not self.has_attention:
            return True
        return self.attention in ("swa", "local_global") or self.hybrid_parallel

    # -- parameter counting (used for MODEL_FLOPS = 6*N*D) -------------
    def param_count(self, active_only: bool = False) -> int:
        d, f = self.d_model, self.d_ff
        emb = self.vocab_size * d
        per_layer = 0
        if self.has_attention:
            per_layer += d * self.d_attn + 2 * d * self.d_kv  # QKV
            per_layer += self.d_attn * d  # O
            if self.qkv_bias:
                per_layer += self.d_attn + 2 * self.d_kv
        if self.has_ssm:
            di = self.d_inner
            per_layer += d * 2 * di  # in_proj (x, z)
            per_layer += d * 2 * self.ssm_state  # B, C proj (ngroups=1, MQA-like)
            per_layer += d * self.ssm_heads  # dt proj
            per_layer += di * self.ssm_conv_width  # depthwise conv
            per_layer += di * d  # out proj
            per_layer += 2 * self.ssm_heads  # A_log, D
        # MLP
        gated = self.mlp in ("swiglu", "geglu")
        mlp_p = d * f * (3 if gated else 2)
        if self.num_experts:
            experts = self.top_k if active_only else self.num_experts
            per_layer += experts * mlp_p + d * self.num_experts  # + router
            if self.dense_residual:
                per_layer += mlp_p
        elif f > 0:
            per_layer += mlp_p
        per_layer += 2 * d  # norms
        total = emb + self.num_layers * per_layer + d
        if not self.tie_embeddings:
            total += self.vocab_size * d  # lm head
        return total

    def validate(self) -> None:
        assert self.d_model > 0 and self.num_layers > 0
        if self.has_attention:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name
        if self.num_experts:
            assert 0 < self.top_k <= self.num_experts
        if self.has_ssm:
            assert self.d_inner % self.ssm_head_dim == 0, (
                f"{self.name}: d_inner={self.d_inner} not divisible by "
                f"ssm_head_dim={self.ssm_head_dim}")
        if self.attention == "local_global":
            assert self.local_per_global > 0 and self.window_size > 0
        if self.attention == "swa":
            assert self.window_size > 0


@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """One precision policy for the whole spectral stack.

    Every boundary that used to assume ``jnp.float32`` consumes this object
    instead — configs own the presets, ``core/fno.py`` applies the
    param/compute casts, ``kernels/ops.py``/``kernels/engine.py`` honor the
    spectral-operand and accumulator dtypes, and ``train/train_step.py``
    takes the grad-accumulation dtype. Cast ownership (ROADMAP.md
    §Precision policy):

      * ``param_dtype``    — master-parameter storage (init + AdamW update).
      * ``compute_dtype``  — activation / kernel I/O dtype; ``apply_fno``
        casts once at the top, the fused layers cast their operands inside
        the custom_vjp so cotangents leave at the *primal* dtypes.
      * ``spectral_dtype`` — the DFT operand matrices (the bundles cached
        in ``core/spectral.py``, keyed on this dtype).
      * ``accum_dtype``    — MXU/VMEM accumulators in the Pallas engine
        (stays f32 under the bf16 preset: casts happen only at ref-write
        boundaries).
      * ``grad_acc_dtype`` — microbatch gradient-accumulation buffer.
    """

    param_dtype: str = "float32"
    compute_dtype: str = "float32"
    spectral_dtype: str = "float32"
    accum_dtype: str = "float32"
    grad_acc_dtype: str = "float32"

    _ALIASES = {"f32": "float32", "float32": "float32",
                "bf16": "bfloat16", "bfloat16": "bfloat16"}

    @classmethod
    def from_name(cls, name: str) -> "PrecisionPolicy":
        """Presets: "f32"/"float32" → pure f32; "bf16"/"bfloat16" → bf16
        compute + spectral operands, f32 master params / accumulators /
        grad accumulation (standard mixed-precision training).

        Any other dtype name falls back to a uniform policy at that dtype
        (params, compute, and spectral operands all at `name`; f32
        accumulation) — preserving the historical ``FNOConfig.dtype``
        contract for e.g. "float64"; the name is validated when the dtype
        is first used."""
        canon = cls._ALIASES.get(name)
        if canon is None:
            return cls(param_dtype=name, compute_dtype=name,
                       spectral_dtype=name)
        if canon == "float32":
            return cls()
        return cls(param_dtype="float32", compute_dtype="bfloat16",
                   spectral_dtype="bfloat16", accum_dtype="float32",
                   grad_acc_dtype="float32")

    @property
    def is_mixed(self) -> bool:
        return self.compute_dtype != self.param_dtype


@dataclasses.dataclass(frozen=True)
class FNOConfig:
    """Fourier Neural Operator configuration (the paper's architecture)."""

    name: str
    ndim: int  # 1, 2, or 3
    hidden: int  # HiddenDim (channels)
    num_layers: int
    in_channels: int
    out_channels: int
    spatial: Tuple[int, ...]  # (N,), (X, Y), or (X, Y, Z)
    modes: Tuple[int, ...]  # kept low-frequency modes per spatial axis
    weight_mode: str = "shared"  # shared (paper CGEMM) | per_mode (classic FNO)
    lifting_dim: int = 0  # 0 => 2*hidden
    path: str = "xla"  # ref | xla | pallas
    dtype: str = "float32"  # precision preset name (PrecisionPolicy.from_name)
    policy: Optional[PrecisionPolicy] = None  # explicit override of `dtype`
    # Whole-block fusion on the pallas path: spectral + 1x1 bypass + bias +
    # GELU in ONE pallas_call per layer (kernels/ops.fno_block_nd). The
    # ref/xla paths ignore it and stay the staged parity oracle.
    fuse_block: bool = False
    # Explicit (bb, bo, bh) launch-plan override for the pallas kernels.
    # None (the default) lets ``repro.tuning.resolve_block_plan`` pick the
    # tuned-cache winner (fallback: ops._BLOCK_DEFAULTS). A component of 0
    # keeps the resolved value for that axis. See configs.fno.with_block_plan.
    block_plan: Optional[Tuple[int, int, int]] = None
    # TP inter-layer collective layout (docs/DESIGN.md §6). "scatter" (the
    # production default) completes each interior layer's sharded k-loop
    # with a psum_scatter that emits the NEXT layer's hidden shard directly
    # — half the collective bytes of "psum", which all-reduces every layer
    # to a replicated pre-activation (the PR-5 layout, kept as the parity/
    # fallback layout). Ignored when TP is off.
    tp_layout: str = "scatter"  # scatter | psum
    # Opt-in comm/compute overlap for the scattered layout: the interior
    # reduce-scatter runs as a ppermute ring (tp-1 chunk hops), whose
    # async collective-permute steps XLA can hide under the neighboring
    # layers' k-loop compute. Same math, same sharding — smoke-checked by
    # scripts/overlap_smoke.py against the one-shot psum_scatter.
    tp_overlap: bool = False
    # Fold the lifting MLP into the FIRST fused block kernel and the
    # projection MLP into the LAST one (engine prologue/epilogue operands)
    # so the non-spectral ends stop round-tripping HBM. Pallas path with
    # fuse_block only; under TP the ends stay staged (the final psum +
    # nonlinearity sit between the last k-loop and the projection — see
    # DESIGN.md §6) and this flag is ignored.
    fuse_ends: bool = False

    @property
    def precision(self) -> PrecisionPolicy:
        """The resolved precision policy (explicit `policy` wins, else the
        `dtype` preset)."""
        return self.policy or PrecisionPolicy.from_name(self.dtype)

    @property
    def truncation_ratio(self) -> Tuple[float, ...]:
        full = tuple(s // 2 + 1 for s in self.spatial)
        return tuple(m / f for m, f in zip(self.modes, full))

    def param_count(self) -> int:
        h = self.hidden
        lift = self.lifting_dim or 2 * h
        p = self.in_channels * lift + lift * h  # lifting MLP
        per_layer = 2 * h * h  # complex shared weight (re+im)
        if self.weight_mode == "per_mode":
            per_layer *= math.prod(self.modes)
        per_layer += h * h + h  # bypass 1x1 conv + bias
        p += self.num_layers * per_layer
        p += h * lift + lift * self.out_channels  # projection MLP
        return p

    def validate(self) -> None:
        assert self.ndim in (1, 2, 3) and len(self.spatial) == self.ndim
        assert len(self.modes) == self.ndim
        for m, s in zip(self.modes, self.spatial):
            assert 0 < m <= s // 2, (
                f"{self.name}: modes {m} must be <= {s // 2} (Nyquist excl.)")
        if self.block_plan is not None:
            assert len(self.block_plan) == 3 and all(
                isinstance(v, int) and v >= 0 for v in self.block_plan), (
                f"{self.name}: block_plan must be 3 non-negative ints, got "
                f"{self.block_plan!r}")
        assert self.tp_layout in ("scatter", "psum"), (
            f"{self.name}: tp_layout must be 'scatter' or 'psum', got "
            f"{self.tp_layout!r}")


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    """One input-shape cell from the assignment."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# Reduced shapes for CPU smoke tests.
SMOKE_SHAPES = {
    "train_4k": ShapeSpec("train_4k", 64, 2, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 128, 1, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 128, 2, "decode"),
    "long_500k": ShapeSpec("long_500k", 256, 1, "decode"),
}
