"""HuBERT-XLarge — encoder-only audio transformer (w2v2 backbone).

The convolutional waveform frontend is a STUB: ``input_specs()`` provides
precomputed frame embeddings of shape [B, T, d_model]. [arXiv:2106.07447]
"""
from repro.configs.base import ModelConfig

ARCH_ID = "hubert-xlarge"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="encoder",
        num_layers=48,
        d_model=1280,
        num_heads=16,
        num_kv_heads=16,
        head_dim=80,
        d_ff=5120,
        vocab_size=504,  # masked-prediction codebook classes
        attention="bidirectional",
        rope_style="none",  # conv positional embedding folded into frontend stub
        mlp="gelu",
        norm="layernorm",
        frontend="audio",
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
        head_dim=16, d_ff=128, vocab_size=64)
