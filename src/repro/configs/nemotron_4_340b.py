"""Nemotron-4-340B — dense GQA decoder, squared-ReLU MLP. [arXiv:2402.16819]"""
from repro.configs.base import ModelConfig

ARCH_ID = "nemotron-4-340b"


def config() -> ModelConfig:
    return ModelConfig(
        name=ARCH_ID,
        family="dense",
        num_layers=96,
        d_model=18432,
        num_heads=96,
        num_kv_heads=8,
        head_dim=192,
        d_ff=73728,
        vocab_size=256000,
        attention="full",
        rope_style="full",
        rope_base=10000.0,
        mlp="relu2",
        norm="layernorm",
    )


def reduced() -> ModelConfig:
    import dataclasses
    return dataclasses.replace(
        config(), num_layers=2, d_model=96, num_heads=4, num_kv_heads=2,
        head_dim=24, d_ff=256, vocab_size=512)
