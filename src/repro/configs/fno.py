"""FNO configurations — the paper's own architecture (TurboFNO's target).

``fno1d``/``fno2d`` match the paper's evaluated sizes: signal lengths
N1=128 / N2=256 (Table 1), truncation ratios 25% and 50% (Sec. 3.1), hidden
dims 32-128 (Sec. 5). ``fno2d-large`` is the end-to-end training target
(~100M params with per-mode weights). ``fno3d`` is the Navier–Stokes-class
rank-3 workload (Li et al. 2020 §5.3 uses 64³ grids; we keep the same 25%
per-axis truncation) running on the rank-generic fused engine.
"""
import dataclasses

from repro.configs.base import FNOConfig, PrecisionPolicy

ARCH_ID_1D = "fno1d"
ARCH_ID_2D = "fno2d"
ARCH_ID_3D = "fno3d"


def with_precision(cfg: FNOConfig, dtype: str) -> FNOConfig:
    """Apply a ``--dtype`` preset ("f32"/"bf16") to an FNO config.

    The resolved :class:`PrecisionPolicy` travels inside the config, so
    every downstream layer (init, apply, fused kernels, train step,
    roofline byte model) sees the same policy object.
    """
    pol = PrecisionPolicy.from_name(dtype)
    return dataclasses.replace(cfg, dtype=pol.compute_dtype, policy=pol)


def with_fuse_block(cfg: FNOConfig, on: bool = True) -> FNOConfig:
    """Toggle whole-block fusion: on the pallas path each FNO layer
    (spectral + 1×1 bypass + bias + GELU) lowers to ONE pallas_call
    (``kernels/ops.fno_block_nd``) instead of a fused spectral kernel plus
    ~4 XLA epilogue ops. Composes with :func:`with_precision`."""
    return dataclasses.replace(cfg, fuse_block=on)


def with_tp_layout(cfg: FNOConfig, layout: str,
                   overlap: bool = False) -> FNOConfig:
    """Pick the TP inter-layer collective layout: "scatter" (the default —
    each interior layer's psum_scatter emits the next layer's hidden shard,
    half the collective bytes) or "psum" (the PR-5 all-reduce-every-layer
    layout, kept as the parity/fallback layout). overlap=True additionally
    runs the interior reduce-scatter as a ppermute ring so XLA can hide
    the chunk hops under k-loop compute (scattered layout only)."""
    return dataclasses.replace(cfg, tp_layout=layout, tp_overlap=overlap)


def with_fuse_ends(cfg: FNOConfig, on: bool = True) -> FNOConfig:
    """Fold the lifting MLP into the first fused block kernel and the
    projection MLP into the last one (pallas path with fuse_block; ignored
    under TP — see DESIGN.md §6)."""
    return dataclasses.replace(cfg, fuse_ends=on)


def with_block_plan(cfg: FNOConfig, bb: int, bo: int, bh: int) -> FNOConfig:
    """Pin an explicit (bb, bo, bh) launch plan, overriding the tuned
    cache (``repro.tuning``) component-wise — a component of 0 keeps the
    resolved value. Composes with :func:`with_precision` /
    :func:`with_fuse_block`."""
    return dataclasses.replace(cfg, block_plan=(bb, bo, bh))


def fno1d() -> FNOConfig:
    return FNOConfig(
        name="fno1d", ndim=1, hidden=64, num_layers=4,
        in_channels=1, out_channels=1,
        spatial=(256,), modes=(64,),  # 50% of N/2+1 ~ paper's k=64 @ N=256
        weight_mode="shared",
    )


def fno2d() -> FNOConfig:
    return FNOConfig(
        name="fno2d", ndim=2, hidden=64, num_layers=4,
        in_channels=3, out_channels=1,  # (a(x,y), x, y) -> u(x,y)
        spatial=(128, 128), modes=(32, 32),  # 50% truncation per axis
        weight_mode="shared",
    )


def fno2d_large() -> FNOConfig:
    """~100M-param per-mode FNO for the end-to-end training example."""
    return FNOConfig(
        name="fno2d-large", ndim=2, hidden=128, num_layers=4,
        in_channels=3, out_channels=1,
        spatial=(128, 128), modes=(32, 32),
        weight_mode="per_mode",
    )


def fno3d() -> FNOConfig:
    """Rank-3 spectral operator (3D diffusion / Navier–Stokes substrate)."""
    return FNOConfig(
        name="fno3d", ndim=3, hidden=32, num_layers=4,
        in_channels=1, out_channels=1,
        spatial=(64, 64, 64), modes=(16, 16, 16),  # 25%/axis truncation
        weight_mode="shared",
    )


def reduced_1d() -> FNOConfig:
    import dataclasses
    return dataclasses.replace(
        fno1d(), hidden=16, num_layers=2, spatial=(64,), modes=(16,))


def reduced_2d() -> FNOConfig:
    import dataclasses
    return dataclasses.replace(
        fno2d(), hidden=16, num_layers=2, spatial=(32, 32), modes=(8, 8))


def reduced_3d() -> FNOConfig:
    import dataclasses
    return dataclasses.replace(
        fno3d(), hidden=8, num_layers=2, spatial=(16, 16, 16),
        modes=(4, 4, 4))
