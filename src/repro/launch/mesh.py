"""Production mesh factories.

Functions (not module-level constants) so importing never touches jax
device state. Production target: TPU v5e, 256 chips/pod, 16x16 (data, model);
multi-pod = 2 pods x 256 = 512 chips with a leading "pod" axis that composes
with data parallelism (docs/DESIGN.md §6).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def make_compat_mesh(shape, axes):
    """Version-safe ``jax.make_mesh`` — the single AxisType shim point
    (ROADMAP.md §JAX version compat).

    jax.sharding.AxisType landed after 0.4.x; omit axis_types when absent
    (pre-AxisType meshes behave as Auto on every axis).
    """
    axis_type = getattr(jax.sharding, "AxisType", None)
    kwargs = ({"axis_types": (axis_type.Auto,) * len(axes)}
              if axis_type is not None else {})
    return jax.make_mesh(shape, axes, **kwargs)


def make_production_mesh(*, multi_pod: bool = False):
    if multi_pod:
        return make_compat_mesh((2, 16, 16), ("pod", "data", "model"))
    return make_compat_mesh((16, 16), ("data", "model"))


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for subprocess tests on N virtual CPU devices."""
    if pod:
        return make_compat_mesh((pod, data, model), ("pod", "data", "model"))
    return make_compat_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def n_chips(mesh) -> int:
    return mesh.devices.size
