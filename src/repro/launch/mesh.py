"""Production mesh factories.

Functions (not module-level constants) so importing never touches jax
device state. Production target: TPU v5e, 256 chips/pod, 16x16 (data, model);
multi-pod = 2 pods x 256 = 512 chips with a leading "pod" axis that composes
with data parallelism (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax


def _mk(shape, axes):
    return jax.make_mesh(
        shape, axes,
        axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False):
    if multi_pod:
        return _mk((2, 16, 16), ("pod", "data", "model"))
    return _mk((16, 16), ("data", "model"))


def make_debug_mesh(data: int = 2, model: int = 2, pod: int = 0):
    """Small mesh for subprocess tests on N virtual CPU devices."""
    if pod:
        return _mk((pod, data, model), ("pod", "data", "model"))
    return _mk((data, model), ("data", "model"))


def batch_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.shape else ("data",)


def n_chips(mesh) -> int:
    return mesh.devices.size
