"""Dry-run cell construction: (arch × shape × mesh) -> jit-able step +
ShapeDtypeStruct args + input shardings + MODEL_FLOPS.

No device allocation happens here: params/optimizer/cache/batch are all
``jax.eval_shape`` stand-ins (the shannon/kernels pattern).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config, get_shape
from repro.configs import fno as fno_cfgs
from repro.configs.base import FNOConfig, ModelConfig, ShapeSpec
from repro.core import fno as fno_mod
from repro.distributed import sharding as shd
from repro.models import transformer as tf
from repro.optim import AdamW
from repro.optim.schedule import cosine_warmup
from repro.roofline import analysis as roof
from repro.train import serve_fno_step as sfs, serve_step, train_step as ts

# per-arch training knobs (memory fitting at 256 chips; EXPERIMENTS.md)
DEFAULT_MICROBATCHES = 8
MICROBATCHES = {
    "nemotron-4-340b": 8, "arctic-480b": 8,
}
OPT_STATE_DTYPE = {
    "nemotron-4-340b": "bfloat16", "arctic-480b": "bfloat16",
}
GRAD_ACC_DTYPE = {
    "nemotron-4-340b": "bfloat16", "arctic-480b": "bfloat16",
}


@dataclasses.dataclass
class Cell:
    arch: str
    shape: str
    step_fn: Callable
    args: Tuple[Any, ...]
    in_shardings: Tuple[Any, ...]
    model_flops: float
    ctx: shd.ShardingContext
    out_shardings: Any = None


def _wrap_ctx(fn, ctx):
    @functools.wraps(fn)
    def wrapped(*a):
        with shd.sharding_context(ctx):
            return fn(*a)
    return wrapped


def _optimizer(arch: str) -> AdamW:
    return AdamW(lr=cosine_warmup(3e-4, 2000, 100_000),
                 state_dtype=OPT_STATE_DTYPE.get(arch))


def _lm_batch_sds(cfg: ModelConfig, shape: ShapeSpec, with_labels: bool):
    b, s = shape.global_batch, shape.seq_len
    out: Dict[str, Any] = {}
    if cfg.frontend == "audio":
        out["inputs_embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                    jnp.bfloat16)
    else:
        s_tok = s - (cfg.num_prefix_embeds if cfg.frontend == "vision" else 0)
        out["tokens"] = jax.ShapeDtypeStruct((b, s_tok), jnp.int32)
        if cfg.frontend == "vision":
            out["prefix_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.num_prefix_embeds, cfg.d_model), jnp.bfloat16)
    if with_labels:
        ls = s if cfg.frontend == "audio" else out["tokens"].shape[1]
        out["labels"] = jax.ShapeDtypeStruct((shape.global_batch, ls),
                                             jnp.int32)
    return out


def build_cell(arch: str, shape_name: str, mesh, *,
               reduced: bool = False, fno_path: Optional[str] = None,
               fno_fuse_block: Optional[bool] = None,
               fno_dtype: Optional[str] = None,
               fno_strategy: Optional[str] = None) -> Cell:
    """(arch × shape × mesh) -> Cell.

    The fno_* knobs override the FNO cell spec (``FNO_CELL_DEFAULTS``:
    pallas path, fused blocks — the production configuration); a non-train
    shape builds the batched FNO *serving* cell (``_build_fno_serve``).
    """
    cfg = get_config(arch, reduced=reduced)
    shape = get_shape(shape_name, reduced=reduced)
    n = mesh.devices.size

    if isinstance(cfg, FNOConfig):
        fno_kw = dict(path=fno_path, fuse_block=fno_fuse_block,
                      dtype=fno_dtype, strategy=fno_strategy)
        if shape.kind == "train":
            return _build_fno_train(arch, cfg, shape, mesh, **fno_kw)
        return _build_fno_serve(arch, cfg, shape, mesh, **fno_kw)
    kind = shape.kind
    if kind == "prefill" and not cfg.is_decoder:
        return _build_encoder(arch, cfg, shape, mesh)
    if kind == "train":
        return _build_lm_train(arch, cfg, shape, mesh, reduced)
    if kind == "prefill":
        return _build_prefill(arch, cfg, shape, mesh)
    return _build_decode(arch, cfg, shape, mesh, shape_name == "long_500k")


# ---------------------------------------------------------------------------
def _build_lm_train(arch, cfg, shape, mesh, reduced):
    ctx = shd.make_context(cfg, mesh, kind="train")
    opt = _optimizer(arch)
    mb = 1 if reduced else MICROBATCHES.get(arch, DEFAULT_MICROBATCHES)
    import jax.numpy as _jnp
    gdt = _jnp.dtype(GRAD_ACC_DTYPE[arch]) if arch in GRAD_ACC_DTYPE else None
    step = ts.make_train_step(cfg, opt, microbatches=mb, remat=not reduced,
                              grad_acc_dtype=gdt)

    with shd.sharding_context(ctx):
        params = jax.eval_shape(
            lambda: tf.init_lm(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
        opt_state = jax.eval_shape(opt.init, params)
    batch = _lm_batch_sds(cfg, shape, with_labels=True)

    pspec = shd.param_specs(cfg, mesh, params)
    ospec = {"m": pspec, "v": pspec, "step": P()}
    bspec = shd.batch_specs(cfg, ctx, batch)
    sh = lambda t: shd.shardings_from_specs(t, mesh)
    mf = roof.lm_model_flops(cfg, "train", shape.seq_len, shape.global_batch)
    return Cell(arch, shape.name, _wrap_ctx(step, ctx),
                (params, opt_state, batch),
                (sh(pspec), sh(ospec), sh(bspec)), mf, ctx)


def _infer_fsdp(cfg, mesh) -> bool:
    """Inference keeps weights TP-sharded only (no per-step weight
    all-gathers) unless params exceed ~8 GiB/chip that way."""
    tp = mesh.shape.get("model", 1)
    return cfg.param_count() * 2 / tp > 8 * 2 ** 30


def _build_prefill(arch, cfg, shape, mesh):
    ctx = shd.make_context(cfg, mesh, kind="prefill")
    step = serve_step.make_prefill_step(cfg, max_len=shape.seq_len)
    with shd.sharding_context(ctx):
        params = jax.eval_shape(
            lambda: tf.init_lm(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    batch = _lm_batch_sds(cfg, shape, with_labels=False)
    pspec = shd.param_specs(cfg, mesh, params, fsdp=_infer_fsdp(cfg, mesh))
    bspec = shd.batch_specs(cfg, ctx, batch)
    sh = lambda t: shd.shardings_from_specs(t, mesh)
    mf = roof.lm_model_flops(cfg, "prefill", shape.seq_len,
                             shape.global_batch)
    return Cell(arch, shape.name, _wrap_ctx(step, ctx), (params, batch),
                (sh(pspec), sh(bspec)), mf, ctx)


def _build_encoder(arch, cfg, shape, mesh):
    ctx = shd.make_context(cfg, mesh, kind="prefill")
    step = serve_step.make_encoder_step(cfg)
    with shd.sharding_context(ctx):
        params = jax.eval_shape(
            lambda: tf.init_lm(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
    batch = _lm_batch_sds(cfg, shape, with_labels=False)
    pspec = shd.param_specs(cfg, mesh, params, fsdp=_infer_fsdp(cfg, mesh))
    bspec = shd.batch_specs(cfg, ctx, batch)
    sh = lambda t: shd.shardings_from_specs(t, mesh)
    mf = roof.lm_model_flops(cfg, "prefill", shape.seq_len,
                             shape.global_batch)
    return Cell(arch, shape.name, _wrap_ctx(step, ctx), (params, batch),
                (sh(pspec), sh(bspec)), mf, ctx)


def _cache_gib(cfg, b, s, ctx, mesh) -> float:
    """Estimated per-chip KV-cache GiB under head+batch sharding."""
    if not cfg.has_attention:
        return 0.0
    tp = mesh.shape.get("model", 1)
    kv_eff = cfg.num_kv_heads * ctx.kv_repeat_factor
    total = cfg.num_layers * b * s * kv_eff * cfg.head_dim * 2 * 2
    div = (min(b, mesh.shape.get("data", 1))
           * (tp if ctx.attn_sharded and kv_eff % tp == 0 else 1))
    return total / div / 2 ** 30


def _build_decode(arch, cfg, shape, mesh, shard_seq: bool):
    ctx = shd.make_context(cfg, mesh, kind="decode")
    b, s = shape.global_batch, shape.seq_len
    seq_axes = None
    if not shard_seq and _cache_gib(cfg, b, s, ctx, mesh) > 8.0:
        # big-cache archs: shard the cache SEQUENCE over the model axis
        # (distributed-softmax decode) instead of KV heads — the only
        # layout where a 340B/32k/128-batch cache fits 16 GiB chips
        ctx = dataclasses.replace(ctx, attn_sharded=False,
                                  kv_repeat_factor=1)
        seq_axes = ("model",)

    def step(params, cache, token):
        return tf.decode_step(params, cfg, cache, token)

    with shd.sharding_context(ctx):
        params = jax.eval_shape(
            lambda: tf.init_lm(jax.random.PRNGKey(0), cfg, jnp.bfloat16))
        cache = jax.eval_shape(
            lambda: tf.init_cache(cfg, b, s, dtype=jnp.bfloat16))
    token = jax.ShapeDtypeStruct((b,), jnp.int32)

    pspec = shd.param_specs(cfg, mesh, params, fsdp=_infer_fsdp(cfg, mesh))
    cspec = shd.cache_specs(cfg, ctx, cache, shard_seq=shard_seq,
                            seq_axes=seq_axes)
    bent = shd._batch_entry(ctx)
    ndata = 1
    for a in ctx.batch_axes:
        ndata *= mesh.shape.get(a, 1)
    tok_spec = P(bent) if b % max(ndata, 1) == 0 else P(None)
    sh = lambda t: shd.shardings_from_specs(t, mesh)
    mf = roof.lm_model_flops(cfg, "decode", s, b)
    emb_tp = mesh.shape.get("model", 1)
    logit_spec = P(bent if b % max(ndata, 1) == 0 else None,
                   "model" if cfg.vocab_size % emb_tp == 0 else None)
    out_sh = (NamedSharding(mesh, logit_spec), sh(cspec))
    return Cell(arch, shape.name, _wrap_ctx(step, ctx),
                (params, cache, token),
                (sh(pspec), sh(cspec), NamedSharding(mesh, tok_spec)), mf,
                ctx, out_shardings=out_sh)


# FNO cell spec (ISSUE 5): the fused pallas path IS the production path.
# Every FNO cell runs the fused kernels with whole-block fusion unless the
# caller overrides; dtype None keeps the config's preset (f32). The DP/TP
# placement comes from shd.make_context — TP over the hidden k-loop axis
# when the model axis divides it, pure DP (model folded into batch)
# otherwise (docs/DESIGN.md §6). TRAINING defaults to pure DP: train_4k is
# the batch ≫ hidden regime, where replicating the tiny FNO weights
# removes every per-layer psum and only the gradient all-reduce remains;
# TP is opt-in via fno_strategy="auto". Serving keeps the auto grid (the
# serve driver balances dp ≥ tp).
FNO_CELL_DEFAULTS = {"path": "pallas", "fuse_block": True, "variant": "full"}
FNO_TRAIN_STRATEGY = "dp"


def _fno_cell_cfg(cfg, path, fuse_block, dtype):
    cfg = dataclasses.replace(
        cfg, path=path or FNO_CELL_DEFAULTS["path"],
        fuse_block=(FNO_CELL_DEFAULTS["fuse_block"]
                    if fuse_block is None else fuse_block))
    if dtype:
        cfg = fno_cfgs.with_precision(cfg, dtype)
    return cfg


def _fno_batch_sds(cfg, b, with_labels):
    out = {"x": jax.ShapeDtypeStruct(
        (b, cfg.in_channels) + tuple(cfg.spatial), jnp.float32)}
    if with_labels:
        out["y"] = jax.ShapeDtypeStruct(
            (b, cfg.out_channels) + tuple(cfg.spatial), jnp.float32)
    return out


def _build_fno_train(arch, cfg, shape, mesh, *, path=None, fuse_block=None,
                     dtype=None, strategy=None):
    cfg = _fno_cell_cfg(cfg, path, fuse_block, dtype)
    ctx = shd.make_context(cfg, mesh, kind="train",
                           fno_strategy=strategy or FNO_TRAIN_STRATEGY)
    opt = _optimizer(arch)
    step = ts.make_train_step(cfg, opt, fno_path=cfg.path,
                              fno_variant=FNO_CELL_DEFAULTS["variant"])
    b = shape.global_batch
    with shd.sharding_context(ctx):
        params = jax.eval_shape(
            lambda: fno_mod.init_fno(jax.random.PRNGKey(0), cfg))
        opt_state = jax.eval_shape(opt.init, params)
    batch = _fno_batch_sds(cfg, b, with_labels=True)
    fno_tp = ctx.model_axis is not None
    pspec = shd.param_specs(cfg, mesh, params, fno_tp=fno_tp)
    ospec = shd.opt_state_specs(cfg, mesh, params, opt_state, fno_tp=fno_tp)
    bspec = shd.batch_specs(cfg, ctx, batch)
    sh = lambda t: shd.shardings_from_specs(t, mesh)
    mf = roof.fno_model_flops(cfg, b)
    return Cell(arch, shape.name, _wrap_ctx(step, ctx),
                (params, opt_state, batch),
                (sh(pspec), sh(ospec), sh(bspec)), mf, ctx)


def _build_fno_serve(arch, cfg, shape, mesh, *, path=None, fuse_block=None,
                     dtype=None, strategy=None):
    """Batched FNO serving cell: one bucketed forward on the DP×TP mesh
    (shape.global_batch is the bucket size; train.serve_fno_step owns the
    request bucketing/padding that feeds it)."""
    cfg = _fno_cell_cfg(cfg, path, fuse_block, dtype)
    ctx = shd.make_context(cfg, mesh, kind="serve", fno_strategy=strategy)
    step = sfs.make_fno_serve_step(cfg,
                                   variant=FNO_CELL_DEFAULTS["variant"])
    b = shape.global_batch
    with shd.sharding_context(ctx):
        params = jax.eval_shape(
            lambda: fno_mod.init_fno(jax.random.PRNGKey(0), cfg))
    batch = _fno_batch_sds(cfg, b, with_labels=False)
    pspec = shd.param_specs(cfg, mesh, params,
                            fno_tp=ctx.model_axis is not None)
    bspec = shd.batch_specs(cfg, ctx, batch)
    sh = lambda t: shd.shardings_from_specs(t, mesh)
    mf = roof.fno_model_flops(cfg, b, training=False)
    return Cell(arch, shape.name, _wrap_ctx(step, ctx), (params, batch),
                (sh(pspec), sh(bspec)), mf, ctx)
