"""Serving driver: batched prefill + autoregressive decode for the LM zoo,
batched bucketed inference for the FNO archs.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --reduced \
        --batch 4 --prompt-len 32 --new-tokens 16
    PYTHONPATH=src python -m repro.launch.serve --arch fno2d --reduced \
        --requests 8 --max-batch 8

``--arch fno{1,2,3}d`` (any FNO id) delegates to ``launch.serve_fno`` —
request bucketing, padding to the fused kernel's batch blocks, and the
DP×TP pallas placement (docs/DESIGN.md §6).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import FNO_IDS, get_config
from repro.models import transformer as tf
from repro.models.frontend import fake_frontend_arrays
from repro.train import serve_step


def main() -> None:
    peek = argparse.ArgumentParser(add_help=False)
    peek.add_argument("--arch", default="qwen2-1.5b")
    known, _ = peek.parse_known_args()
    if known.arch in FNO_IDS:
        from repro.launch import serve_fno
        serve_fno.main()
        return

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    assert cfg.is_decoder, "encoder-only archs have no decode loop"
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg, jnp.float32)
    max_len = args.prompt_len + args.new_tokens

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    extra = fake_frontend_arrays(cfg, args.batch, args.prompt_len, key)

    prefill = jax.jit(serve_step.make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(serve_step.make_decode_step(cfg))

    t0 = time.time()
    logits, cache = prefill(params, {"tokens": prompts, **extra})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    out = [tok]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        tok, _, cache = decode(params, cache, tok)
        out.append(tok)
    jax.block_until_ready(tok)
    t_dec = time.time() - t0
    gen = jnp.stack(out, 1)
    print(f"arch={args.arch} batch={args.batch} "
          f"prefill({args.prompt_len} toks)={t_prefill*1e3:.0f}ms "
          f"decode={t_dec/max(args.new_tokens-1,1)*1e3:.1f}ms/tok")
    print("generated tokens[0]:", gen[0].tolist())


if __name__ == "__main__":
    main()
