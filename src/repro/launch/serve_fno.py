"""Batched FNO serving driver — the production inference path (ISSUE 5).

    PYTHONPATH=src python -m repro.launch.serve --arch fno2d --reduced \
        --requests 8 --max-batch 8

Request batches of random sizes are bucketed and padded to the fused
kernel's batch blocks (``train.serve_fno_step``), each bucket gets one jit
cache entry, and the forward runs on a (data × model) mesh over the local
devices: DP shards the batch, TP shards the hidden k-loop axis when it
divides (docs/DESIGN.md §6). On the default pallas path the driver also
asserts the fusion contract — one pallas_call per FNO layer — and that
every served output is finite, so it doubles as the CI serving smoke
(scripts/check.sh).
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FNO_IDS, get_config
from repro.configs.fno import with_precision
from repro.core import fno as fno_mod
from repro.distributed import sharding as shd
from repro.launch.mesh import make_compat_mesh
from repro.train import serve_fno_step as sfs


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="fno2d", choices=list(FNO_IDS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=8,
                    help="number of synthetic request batches to serve")
    ap.add_argument("--max-batch", type=int, default=8,
                    help="largest request batch (and bucket ceiling)")
    ap.add_argument("--path", default="pallas",
                    choices=["ref", "xla", "pallas"])
    ap.add_argument("--variant", default="full", choices=["full", "partial"])
    ap.add_argument("--dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--no-fuse-block", action="store_true",
                    help="serve the staged (unfused-block) pallas path")
    ap.add_argument("--rollout-steps", type=int, default=1,
                    help="serve K-step autoregressive rollouts: step t+1 "
                         "runs on step t's output inside ONE jitted "
                         "lax.scan (device-resident — the carry never "
                         "leaves HBM; docs/DESIGN.md §10)")
    ap.add_argument("--replay", action="store_true",
                    help="traffic replay through the async continuous-"
                         "batching tier: a seeded Poisson-ish arrival "
                         "schedule (no wall-clock randomness) coalesced "
                         "into kernel-block buckets on a virtual clock, "
                         "printing p50/p99 latency and queue-depth next "
                         "to throughput (docs/DESIGN.md §10)")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="--replay arrival rate in requests/s")
    ap.add_argument("--deadline-ms", type=float, default=100.0,
                    help="--replay per-request deadline (milliseconds)")
    ap.add_argument("--chaos", action="store_true",
                    help="replay the standard fault plan (kernel fault, "
                         "NaN injection, replica kill, corrupt checkpoint) "
                         "through the resilient runtime and print pool/"
                         "degradation stats (docs/DESIGN.md §9)")
    ap.add_argument("--replicas", type=int, default=2,
                    help="replica-pool size for --chaos")
    ap.add_argument("--dp", type=int, default=0,
                    help="data-parallel shards (0 = devices // tp)")
    ap.add_argument("--tp", type=int, default=0,
                    help="tensor-parallel shards over hidden (0 = auto: "
                         "the largest divisor of both the device count and "
                         "hidden that keeps dp >= tp — FNO serving is "
                         "batch-throughput-bound, so DP gets the devices "
                         "TP can't use)")
    return ap


def _pick_tp(n_dev: int, hidden: int) -> int:
    best = 1
    for tp in range(2, n_dev + 1):
        if n_dev % tp == 0 and hidden % tp == 0 and n_dev // tp >= tp:
            best = tp
    return best


def run(args) -> dict:
    cfg = with_precision(get_config(args.arch, reduced=args.reduced),
                         args.dtype)
    fuse = args.path == "pallas" and not args.no_fuse_block
    cfg = dataclasses.replace(cfg, path=args.path, fuse_block=fuse)

    n_dev = jax.device_count()
    tp = args.tp or _pick_tp(n_dev, cfg.hidden)
    dp = args.dp or max(n_dev // tp, 1)
    if dp * tp > n_dev:
        raise SystemExit(
            f"serve_fno: requested mesh dp{dp}xtp{tp} needs {dp * tp} "
            f"devices but only {n_dev} are visible — pass --dp/--tp whose "
            f"product fits the host (or omit them for the auto grid)")
    mesh = make_compat_mesh((dp, tp), ("data", "model"))
    ctx = shd.make_context(cfg, mesh, kind="serve")

    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    if args.chaos:
        return _run_chaos(args, cfg, ctx, params, key, dp, tp)
    if args.replay:
        return _run_replay(args, cfg, ctx, params, key, dp, tp)
    server = sfs.FNOServer(cfg, params, ctx=ctx, path=args.path,
                           variant=args.variant, max_batch=args.max_batch)

    # Fusion contract (trace-level, robust to interpret mode): ONE
    # pallas_call per FNO layer on the fused-block path, even through the
    # shard_map dispatch. Only the full-fusion variant makes this promise —
    # the paper-faithful partial variant legitimately runs a multi-kernel
    # spectral pipeline per layer. Checked through the contract-linter
    # framework (the same checker scripts/lint.py --trace sweeps). A
    # K-step rollout makes the SAME promise for any K (the scan body
    # traces once — docs/DESIGN.md §10).
    if fuse and args.variant == "full":
        import functools

        from repro.analysis import format_findings
        from repro.analysis.jaxpr_lint import (check_pallas_count,
                                               serve_step_contract)
        findings = serve_step_contract(server, cfg)
        if args.rollout_steps > 1:
            xb = jnp.zeros((server.buckets[0], cfg.in_channels)
                           + tuple(cfg.spatial), jnp.float32)
            findings += check_pallas_count(
                functools.partial(server.rollout_step_fn,
                                  steps=args.rollout_steps),
                (params, {"x": xb}), cfg.num_layers,
                target=f"{cfg.name} rollout K={args.rollout_steps}")
        assert not findings, format_findings(findings)

    rng = np.random.default_rng(0)
    sizes = rng.integers(1, args.max_batch + 1, size=args.requests)
    # Warm the jit cache (one compile per bucket) outside the timed loop.
    for b in server.buckets:
        jax.block_until_ready(server(jnp.zeros(
            (b, cfg.in_channels) + tuple(cfg.spatial), jnp.float32),
            rollout_steps=args.rollout_steps))

    # Pre-build the request batches and validate outputs after the clock
    # stops, so samples_per_s measures the serve steps — not input
    # generation or the device->host transfer of the finite check.
    reqs = [jax.random.normal(jax.random.fold_in(key, i),
                              (int(n), cfg.in_channels) + tuple(cfg.spatial))
            for i, n in enumerate(sizes)]
    jax.block_until_ready(reqs)
    t0 = time.time()
    ys = [server(x, rollout_steps=args.rollout_steps) for x in reqs]
    jax.block_until_ready(ys)
    dt = time.time() - t0
    for y in ys:
        assert np.isfinite(np.asarray(y)).all(), "non-finite serve output"

    samples = int(sizes.sum())
    plan = server.collective_plan()
    out = {
        "arch": args.arch, "path": args.path, "fuse_block": fuse,
        "dp": dp, "tp": tp, "buckets": list(server.buckets),
        "rollout_steps": args.rollout_steps,
        "requests": args.requests, "samples": samples,
        "padded": server.stats["padded"],
        "samples_per_s": samples / max(dt, 1e-9),
        "collective_plan": plan,
    }
    print(f"serve_fno arch={args.arch} mesh=dp{dp}xtp{tp} path={args.path} "
          f"fuse_block={fuse} dtype={args.dtype} "
          f"buckets={list(server.buckets)}")
    print(f"  collective plan: interior={plan['interior_collective']} "
          f"final={plan['final_collective']} "
          f"layout={plan['tp_layout']} overlap={plan['tp_overlap']} "
          f"wire={plan['wire_bytes_per_fwd'] / 2**10:.1f}KiB/fwd")
    print(f"  served {args.requests} requests / {samples} samples "
          f"(rollout K={args.rollout_steps}) in "
          f"{dt*1e3:.0f} ms ({out['samples_per_s']:.1f} samples/s, "
          f"{server.stats['padded']} padded), all outputs finite")
    return out


def _run_replay(args, cfg, ctx, params, key, dp, tp) -> dict:
    """--replay: the async continuous-batching tier under a seeded
    Poisson-ish traffic replay (docs/DESIGN.md §10). The arrival schedule
    is a pure function of the seed; the event loop runs on a virtual
    clock with a per-bucket service model CALIBRATED from this host's
    measured step times, so the p50/p99 rows reflect the machine while
    the admission/coalescing decisions stay deterministic given the
    calibration. scripts/serve_replay_smoke.py is the stricter CI gate
    (fixed synthetic service model → machine-independent exact counts)."""
    from repro.train import serve_queue as sq
    from repro.train import serve_runtime as srt

    rs = srt.ResilientServer(cfg, params, replicas=args.replicas, ctx=ctx,
                             variant=args.variant,
                             max_batch=args.max_batch,
                             queue_limit=max(args.requests, 1), seed=0)
    buckets = rs.primary.buckets
    steps = args.rollout_steps
    # Calibrate the virtual-time service model: median of 3 measured
    # calls per (bucket, steps) after a warmup compile.
    base = {}
    for b in buckets:
        xb = jnp.zeros((b, cfg.in_channels) + tuple(cfg.spatial),
                       jnp.float32)
        jax.block_until_ready(rs.primary(xb, rollout_steps=steps))
        ts = []
        for _ in range(3):
            t0 = time.time()
            jax.block_until_ready(rs.primary(xb, rollout_steps=steps))
            ts.append(time.time() - t0)
        base[b] = float(np.median(ts))
    service_model = lambda bucket, k: base[bucket]  # noqa: E731

    cbs = sq.ContinuousBatchingServer(
        rs, queue_limit=args.max_batch * 2, coalesce_s=2.0 / args.rate,
        clock=sq.VirtualClock(), service_model=service_model)
    sched = sq.poisson_schedule(
        0, args.requests, rate_hz=args.rate, max_n=args.max_batch,
        rollout_steps=steps, deadline_s=args.deadline_ms * 1e-3)

    def input_fn(a, i):
        return np.asarray(jax.random.normal(
            jax.random.fold_in(key, i),
            (a.n, cfg.in_channels) + tuple(cfg.spatial)))

    rep = cbs.replay(sched, input_fn)
    for r in cbs.requests.values():
        if r.status == "done":
            assert np.isfinite(np.asarray(r.y)).all(), \
                "non-finite replay output"
    s, lat, qd = rep["stats"], rep["latency"], rep["queue_depth"]
    print(f"serve_fno --replay arch={args.arch} mesh=dp{dp}xtp{tp} "
          f"rate={args.rate:.0f}req/s deadline={args.deadline_ms:.0f}ms "
          f"rollout K={steps} buckets={list(buckets)}")
    print(f"  admission: offered={s['offered']} accepted={s['accepted']} "
          f"shed={s['shed']} deadline_exceeded={s['deadline_exceeded']} "
          f"completed={s['completed']}")
    print(f"  batching: batches={s['batches']} coalesced={s['coalesced']} "
          f"queue_depth p50={qd['p50']:.1f} p99={qd['p99']:.1f} "
          f"max={qd['max']:.0f}")
    print(f"  latency: p50={lat['p50']*1e3:.2f}ms p99={lat['p99']*1e3:.2f}ms "
          f"mean={lat['mean']*1e3:.2f}ms over {lat['count']} completed "
          f"({rep['served_samples']} samples, "
          f"makespan {rep['makespan_s']*1e3:.0f}ms virtual)")
    return {"arch": args.arch, "dp": dp, "tp": tp, **rep}


def _run_chaos(args, cfg, ctx, params, key, dp, tp) -> dict:
    """--chaos: replay the standard deterministic fault plan through the
    resilient runtime (ResilientServer), asserting every accepted request
    is answered finite, then print the pool/degradation stats next to the
    collective plan. scripts/chaos_smoke.py is the stricter CI gate; this
    mode is the operator-facing replay."""
    import tempfile

    from repro.checkpoint import Checkpointer
    from repro.distributed import faults as flt
    from repro.train import serve_runtime as srt

    plan = flt.standard_chaos_plan()
    with tempfile.TemporaryDirectory() as ckdir:
        ck = Checkpointer(ckdir)
        rs = srt.ResilientServer(
            cfg, params, replicas=args.replicas, ctx=ctx,
            variant=args.variant, max_batch=args.max_batch,
            queue_limit=max(args.requests, 1), fault_plan=plan,
            checkpointer=ck, seed=0, backoff_base_s=1e-3)
        rng = np.random.default_rng(0)
        sizes = rng.integers(1, args.max_batch + 1, size=args.requests)
        t0 = time.time()
        ys = []
        for i, n in enumerate(sizes):
            x = jax.random.normal(
                jax.random.fold_in(key, i),
                (int(n), cfg.in_channels) + tuple(cfg.spatial))
            ys.append(rs(x))
        dt = time.time() - t0
        for y in ys:
            assert np.isfinite(y).all(), "non-finite chaos serve output"
        # The corrupt-checkpoint leg: a corrupted-on-disk step must make
        # the hot reload roll back (old params keep serving).
        ck.save(1, params)
        flt.corrupt_checkpoint(ckdir, 1)
        assert rs.reload() is False, "reload of a corrupt ckpt must roll back"

        report = rs.pool_report()
        plan_srv = rs.primary.collective_plan()
        samples = int(sizes.sum())
        print(f"serve_fno --chaos arch={args.arch} mesh=dp{dp}xtp{tp} "
              f"replicas={args.replicas} requests={args.requests}")
        print(f"  collective plan: interior={plan_srv['interior_collective']} "
              f"final={plan_srv['final_collective']} "
              f"layout={plan_srv['tp_layout']} overlap={plan_srv['tp_overlap']} "
              f"wire={plan_srv['wire_bytes_per_fwd'] / 2**10:.1f}KiB/fwd")
        print(f"  pool: {report['replicas']}")
        print(f"  stats: accepted={report['accepted']} "
              f"served={report['served']} degraded={report['degraded']} "
              f"shed={report['shed']} failovers={report['failovers']} "
              f"quarantined={report['quarantined']} "
              f"reinstated={report['reinstated']} "
              f"rollbacks={report['rollbacks']}")
        print(f"  served {samples} samples in {dt*1e3:.0f} ms under the "
              f"fault plan; all outputs finite")
        return {"arch": args.arch, "dp": dp, "tp": tp, **report}


def main() -> None:
    run(build_parser().parse_args())


if __name__ == "__main__":
    main()
