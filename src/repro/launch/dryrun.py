import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production meshes, print memory/cost analysis, and emit roofline rows.

    PYTHONPATH=src python -m repro.launch.dryrun                # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b \
        --shape train_4k --mesh single --json out.json

The XLA_FLAGS line above MUST precede any jax import (jax locks the device
count at first init). Smoke tests / benches never import this module.
"""
import argparse  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import ALL_IDS, SHAPES, skip_reason  # noqa: E402
from repro.launch import cells as cells_mod  # noqa: E402
from repro.launch.mesh import make_production_mesh  # noqa: E402
from repro.roofline import analysis as roof  # noqa: E402
from repro.roofline import hw  # noqa: E402


def run_cell(arch: str, shape: str, mesh, mesh_name: str, verbose: bool
             ) -> dict:
    t0 = time.time()
    cell = cells_mod.build_cell(arch, shape, mesh)
    # donate params/opt (train) or cache (decode): outputs alias inputs,
    # as any real training/serving loop would run
    donate = (0, 1) if len(cell.args) == 3 and shape != "decode_32k" and \
        shape != "long_500k" else ((1,) if len(cell.args) == 3 else ())
    kw = {}
    if cell.out_shardings is not None:
        kw["out_shardings"] = cell.out_shardings
    jitted = jax.jit(cell.step_fn, in_shardings=cell.in_shardings,
                     donate_argnums=donate, **kw)
    lowered = jitted.lower(*cell.args)
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    ma = compiled.memory_analysis()
    r = roof.analyze(compiled, arch=arch, shape=shape, mesh_name=mesh_name,
                     chips=mesh.devices.size, model_flops=cell.model_flops)
    per_chip = (ma.argument_size_in_bytes + ma.output_size_in_bytes
                + ma.temp_size_in_bytes)
    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_name,
        "chips": mesh.devices.size,
        "status": "ok",
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "arg_bytes": ma.argument_size_in_bytes,
        "out_bytes": ma.output_size_in_bytes,
        "temp_bytes": ma.temp_size_in_bytes,
        "hbm_per_chip_gib": round(per_chip / 2**30, 3),
        "fits_hbm": bool(per_chip <= hw.HBM_BYTES),
        "hlo_flops": r.hlo_flops,
        "hlo_bytes": r.hlo_bytes,
        "coll_bytes": r.coll_bytes,
        "coll_detail": r.coll_detail,
        "model_flops": r.model_flops,
        "t_compute_ms": r.t_compute * 1e3,
        "t_memory_ms": r.t_memory * 1e3,
        "t_collective_ms": r.t_collective * 1e3,
        "bottleneck": r.bottleneck,
        "useful_flop_ratio": r.useful_flop_ratio,
        "mfu_bound": r.mfu_bound,
    }
    if verbose:
        print(f"  memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}"
              f"GiB out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB per chip "
              f"(fits 16GiB: {rec['fits_hbm']})")
        print("  " + roof.HEADER)
        print("  " + r.row())
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="one arch id (default: all)")
    ap.add_argument("--shape", default=None, help="one shape (default: all)")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--json", default=None, help="append records to file")
    ap.add_argument("--quiet", action="store_true")
    args = ap.parse_args()

    assert len(jax.devices()) == 512, "dryrun needs 512 placeholder devices"
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single(16x16)", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi(2x16x16)", make_production_mesh(multi_pod=True)))

    archs = [args.arch] if args.arch else list(ALL_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)

    records, failures = [], []
    for mesh_name, mesh in meshes:
        # single-pod mesh uses 256 of the 512 devices
        for arch in archs:
            for shape in shapes:
                reason = skip_reason(arch, shape)
                if reason:
                    records.append({"arch": arch, "shape": shape,
                                    "mesh": mesh_name, "status": "skip",
                                    "reason": reason})
                    if not args.quiet:
                        print(f"[skip] {arch} × {shape} × {mesh_name}: "
                              f"{reason}")
                    continue
                if not args.quiet:
                    print(f"[cell] {arch} × {shape} × {mesh_name} ...",
                          flush=True)
                try:
                    rec = run_cell(arch, shape, mesh, mesh_name,
                                   verbose=not args.quiet)
                    records.append(rec)
                except Exception as e:  # noqa: BLE001
                    traceback.print_exc()
                    failures.append((arch, shape, mesh_name, repr(e)))
                    records.append({"arch": arch, "shape": shape,
                                    "mesh": mesh_name, "status": "fail",
                                    "error": repr(e)})

    if args.json:
        with open(args.json, "a") as f:
            for r in records:
                f.write(json.dumps(r) + "\n")

    ok = sum(1 for r in records if r.get("status") == "ok")
    sk = sum(1 for r in records if r.get("status") == "skip")
    print(f"\ndry-run: {ok} ok, {sk} skipped, {len(failures)} failed")
    for f in failures:
        print("  FAIL:", *f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
