"""Paper Figs. 11-13 / 16-18 — progressive kernel fusion:
FFT+CGEMM (B), CGEMM+iFFT (C), fully fused FFT-CGEMM-iFFT (D).

derived = speedup over the staged baseline (A-level FFT-optimized pipeline
is also printed for reference) and modeled HBM traffic ratios."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks import pipelines as pl
from benchmarks.common import row, time_fn

PIPES = [("fft_opt", pl.fft_opt), ("fused_fgemm", pl.fused_fgemm),
         ("fused_gemmi", pl.fused_gemmi), ("fused_full", pl.fused_full)]


def run(quick: bool = False):
    print("# bench_fusion (paper Fig.11-13/16-18): name,us_per_call,derived")
    rng = np.random.default_rng(0)
    n = 256
    cases = [(32, 2048), (64, 2048), (128, 2048)]
    if quick:
        cases = cases[:1]
    for h, bs in cases:
        k = n // 4
        o = h
        b = bs // h
        x = jnp.asarray(rng.normal(size=(b, h, n)), jnp.float32)
        wr = jnp.asarray(rng.normal(size=(o, h)) / h, jnp.float32)
        wi = jnp.asarray(rng.normal(size=(o, h)) / h, jnp.float32)
        t_base = time_fn(pl.baseline_staged, x, wr, wi, k)
        for name, fn in PIPES:
            t = time_fn(fn, x, wr, wi, k)
            traffic = (pl.traffic_bytes(b, h, o, n, k, "baseline")
                       / pl.traffic_bytes(b, h, o, n, k,
                                          name if name != "fft_opt"
                                          else "fft_opt"))
            row(f"{name}_K{h}_BS{bs}", t,
                f"speedup={t_base / t:.2f}x traffic_ratio={traffic:.2f}")


if __name__ == "__main__":
    run()
