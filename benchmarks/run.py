"""Benchmark driver — one module per paper table/figure family.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Prints ``name,us_per_call,derived`` CSV rows. Wall-times are CPU XLA
timings (ratios meaningful, absolutes are not TPU numbers); `derived`
carries the paper-figure quantity (speedup / op fraction / traffic ratio).
TPU roofline numbers live in the dry-run path (repro.launch.dryrun) and
EXPERIMENTS.md.
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweeps (CI mode)")
    ap.add_argument("--only", default=None,
                    help="comma-separated subset: prune,kernels,fft_opt,"
                         "fusion,e2e,serve,train,tuned")
    ap.add_argument("--autotune", action="store_true",
                    help="regenerate the tuned block-plan cache "
                         "(scripts/autotune.py) before benchmarking, so "
                         "the tuned rows measure fresh winners")
    ap.add_argument("--ranks", default="1,2,3",
                    help="spatial ranks for the train rank sweep "
                         "(e.g. --ranks 3 tracks only the 3D path)")
    args = ap.parse_args()
    try:
        ranks = tuple(int(r) for r in args.ranks.split(","))
    except ValueError:
        ranks = ()
    if not ranks or any(r not in (1, 2, 3) for r in ranks):
        ap.error(f"--ranks must be a comma-separated subset of 1,2,3 "
                 f"(got {args.ranks!r})")

    if args.autotune:
        from repro.tuning import autotune
        autotune.tune(measure="auto" if not args.quick else "none")
        print()

    from benchmarks import (bench_e2e, bench_fft_opt, bench_fusion,
                            bench_kernels, bench_prune, bench_train)
    table = {
        "prune": lambda: bench_prune.run(),
        "kernels": lambda: bench_kernels.run(args.quick),
        "fft_opt": lambda: bench_fft_opt.run(args.quick),
        "fusion": lambda: bench_fusion.run(args.quick),
        "e2e": lambda: bench_e2e.run(args.quick),
        "serve": lambda: bench_e2e.run_serve(args.quick),
        "train": lambda: bench_train.run(args.quick, ranks=ranks),
        "tuned": lambda: bench_e2e.run_tuned(args.quick),
    }
    # "e2e" already includes the serving AND tuned rows; don't run them
    # twice on a full sweep.
    only = args.only.split(",") if args.only else \
        [k for k in table if k not in ("serve", "tuned")]
    for name in only:
        table[name]()
        print()


if __name__ == "__main__":
    main()
