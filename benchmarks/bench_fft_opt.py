"""Paper Fig. 10 / 15 — FFT pruning + truncation + zero-padding vs the
PyTorch-style staged baseline. derived = measured speedup and modeled HBM
traffic ratio."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import pipelines as pl
from benchmarks.common import row, time_fn


def run(quick: bool = False):
    print("# bench_fft_opt (paper Fig.10/15): name,us_per_call,derived")
    rng = np.random.default_rng(0)
    n = 256
    cases = [(16, 1024), (32, 1024), (64, 1024), (128, 1024),
             (32, 4096), (32, 16384)]
    if quick:
        cases = cases[:2]
    for h, bs in cases:
        for k in (n // 8, n // 4):  # 25% and 50% of N/2
            o = h
            x = jnp.asarray(rng.normal(size=(bs // h, h, n)), jnp.float32)
            wr = jnp.asarray(rng.normal(size=(o, h)) / h, jnp.float32)
            wi = jnp.asarray(rng.normal(size=(o, h)) / h, jnp.float32)
            t_base = time_fn(pl.baseline_staged, x, wr, wi, k)
            t_opt = time_fn(pl.fft_opt, x, wr, wi, k)
            b = x.shape[0]
            traffic = (pl.traffic_bytes(b, h, o, n, k, "baseline")
                       / pl.traffic_bytes(b, h, o, n, k, "fft_opt"))
            row(f"fft_opt_K{h}_BS{bs}_k{k}", t_opt,
                f"speedup={t_base / t_opt:.2f}x traffic_ratio={traffic:.2f}")


if __name__ == "__main__":
    run()
