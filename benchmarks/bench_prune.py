"""Paper Fig. 5 — FFT butterfly pruning op counts, plus the TPU decision:
pruned-FFT (VPU) vs truncated-DFT matmul (MXU) effective cost.

derived column: kept-op fraction (paper claims 37.5% @ 25% trunc, 75% @ 50%
on the 4-point example; 25%-67.5% compute savings overall)."""
from __future__ import annotations

from repro.core import spectral as sp
from repro.roofline import hw

from benchmarks.common import row

MXU_VPU_RATIO = 25.0  # ~197 TFLOP/s MXU vs ~8 TFLOP/s VPU per chip


def run():
    print("# bench_prune (paper Fig.5): name,us_per_call,derived")
    for n, k in [(4, 1), (4, 2), (128, 32), (128, 64), (256, 64),
                 (256, 128), (512, 128)]:
        kept = sp.pruned_fft_ops(n, k) / sp.fft_ops(n)
        row(f"prune_ops_n{n}_k{k}", 0.0, f"kept_frac={kept:.4f}")
    # effective-time comparison of the two truncated-transform strategies
    for n, k in [(128, 32), (256, 64), (256, 128), (1024, 256),
                 (4096, 1024)]:
        t_fft = sp.pruned_fft_flops(n, k)  # VPU ops
        t_dft = sp.truncated_dft_matmul_flops(n, k, False) / MXU_VPU_RATIO
        row(f"prune_vs_dftmm_n{n}_k{k}", 0.0,
            f"dft_matmul_speedup={t_fft / t_dft:.2f}x")


if __name__ == "__main__":
    run()
