"""Benchmark harness utilities.

Wall-times are CPU XLA timings (both sides of every comparison run on the
same backend, so RATIOS are meaningful even though absolute numbers are not
TPU numbers). Each row prints ``name,us_per_call,derived`` where `derived`
carries the analytically-derived quantity the paper's figure reports
(speedup, bytes ratio, op ratio, ...).
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (blocking)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line)
    return line
