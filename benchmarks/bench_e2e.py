"""Paper Fig. 14 / 19 — end-to-end TurboFNO vs PyTorch-style baseline over a
(K, BS) grid, 1D and 2D. derived = speedup (the paper's heatmap cell) —
paper reports avg 44% (1D) / 67% (2D), max 150-250%."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import pipelines as pl
from benchmarks.common import row, time_fn
from repro.kernels import ops, ref as ref_k


# ---- 2D pipelines ----------------------------------------------------------
@jax.jit
def _rfft2(x):
    xf = jnp.fft.rfft2(x, axes=(-2, -1))
    return xf.real, xf.imag


@functools.partial(jax.jit, static_argnames=("kx", "ky"))
def _trunc2(xr, xi, kx, ky):
    return xr[..., :kx, :ky].copy(), xi[..., :kx, :ky].copy()


@jax.jit
def _cgemm2(wr, wi, xr, xi):
    yr = jnp.einsum("oh,bhxy->boxy", wr, xr) - jnp.einsum("oh,bhxy->boxy", wi, xi)
    yi = jnp.einsum("oh,bhxy->boxy", wr, xi) + jnp.einsum("oh,bhxy->boxy", wi, xr)
    return yr, yi


@functools.partial(jax.jit, static_argnames=("nx", "ny"))
def _pad_irfft2(yr, yi, nx, ny):
    kx, ky = yr.shape[-2:]
    pad = [(0, 0), (0, 0), (0, nx - kx), (0, ny // 2 + 1 - ky)]
    yf = jnp.pad(yr + 1j * yi, pad)
    return jnp.fft.irfft2(yf, s=(nx, ny), axes=(-2, -1))


def baseline2d(x, wr, wi, kx, ky):
    nx, ny = x.shape[-2:]
    fr, fi = _rfft2(x)
    tr, ti = _trunc2(fr, fi, kx, ky)
    yr, yi = _cgemm2(wr, wi, tr, ti)
    return _pad_irfft2(yr, yi, nx, ny)


@functools.partial(jax.jit, static_argnames=("kx", "ky"))
def turbo2d(x, wr, wi, kx, ky):
    return ops.spectral_layer_2d(x, wr, wi, (kx, ky), path="xla")


def run(quick: bool = False):
    print("# bench_e2e (paper Fig.14/19): name,us_per_call,derived")
    rng = np.random.default_rng(0)
    # --- 1D grid ---
    n = 256
    grid = [(16, 512), (32, 2048), (64, 8192), (128, 8192)]
    if quick:
        grid = grid[:2]
    speedups = []
    for h, bs in grid:
        k = n // 4
        b = max(1, bs // h)
        x = jnp.asarray(rng.normal(size=(b, h, n)), jnp.float32)
        wr = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        wi = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        t_base = time_fn(pl.baseline_staged, x, wr, wi, k)
        t_turbo = time_fn(pl.fused_full, x, wr, wi, k)
        s = t_base / t_turbo
        speedups.append(s)
        row(f"e2e1d_K{h}_BS{bs}", t_turbo, f"speedup={s:.2f}x")
    row("e2e1d_avg", 0.0,
        f"avg_speedup={np.mean(speedups):.2f}x max={np.max(speedups):.2f}x")

    # --- 2D grid ---
    nx = ny = 64 if quick else 128
    grid2 = [(16, 8), (32, 8), (64, 4)]
    if quick:
        grid2 = grid2[:1]
    speedups2 = []
    for h, b in grid2:
        kx, ky = nx // 4, ny // 4
        x = jnp.asarray(rng.normal(size=(b, h, nx, ny)), jnp.float32)
        wr = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        wi = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        t_base = time_fn(baseline2d, x, wr, wi, kx, ky)
        t_turbo = time_fn(turbo2d, x, wr, wi, kx, ky)
        s = t_base / t_turbo
        speedups2.append(s)
        row(f"e2e2d_K{h}_B{b}", t_turbo, f"speedup={s:.2f}x")
    row("e2e2d_avg", 0.0,
        f"avg_speedup={np.mean(speedups2):.2f}x max={np.max(speedups2):.2f}x")


if __name__ == "__main__":
    run()
