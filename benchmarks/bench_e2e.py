"""Paper Fig. 14 / 19 — end-to-end TurboFNO vs PyTorch-style baseline over a
(K, BS) grid, 1D and 2D. derived = speedup (the paper's heatmap cell) —
paper reports avg 44% (1D) / 67% (2D), max 150-250%.

Plus the PR-4 fused-BLOCK row pair: one whole FNO block
gelu(spectral + bypass + bias) unfused (fused spectral kernel + XLA tail)
vs fully fused (ONE pallas_call) — wall time, modeled HBM bytes, and
kernel-call count (pallas_calls + total traced primitives).

Plus the PR-5 SERVING row pair: the batched FNO serve step (fused vs
unfused block) on a DP×TP mesh over the local devices — throughput in
samples/s. Row schema and the committed BENCH_*.json baselines are
documented in benchmarks/README.md."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import pipelines as pl
from benchmarks.common import row, time_fn
from repro.kernels import ops, ref as ref_k


# ---- 2D pipelines ----------------------------------------------------------
@jax.jit
def _rfft2(x):
    xf = jnp.fft.rfft2(x, axes=(-2, -1))
    return xf.real, xf.imag


@functools.partial(jax.jit, static_argnames=("kx", "ky"))
def _trunc2(xr, xi, kx, ky):
    return xr[..., :kx, :ky].copy(), xi[..., :kx, :ky].copy()


@jax.jit
def _cgemm2(wr, wi, xr, xi):
    yr = jnp.einsum("oh,bhxy->boxy", wr, xr) - jnp.einsum("oh,bhxy->boxy", wi, xi)
    yi = jnp.einsum("oh,bhxy->boxy", wr, xi) + jnp.einsum("oh,bhxy->boxy", wi, xr)
    return yr, yi


@functools.partial(jax.jit, static_argnames=("nx", "ny"))
def _pad_irfft2(yr, yi, nx, ny):
    kx, ky = yr.shape[-2:]
    pad = [(0, 0), (0, 0), (0, nx - kx), (0, ny // 2 + 1 - ky)]
    yf = jnp.pad(yr + 1j * yi, pad)
    return jnp.fft.irfft2(yf, s=(nx, ny), axes=(-2, -1))


def baseline2d(x, wr, wi, kx, ky):
    nx, ny = x.shape[-2:]
    fr, fi = _rfft2(x)
    tr, ti = _trunc2(fr, fi, kx, ky)
    yr, yi = _cgemm2(wr, wi, tr, ti)
    return _pad_irfft2(yr, yi, nx, ny)


@functools.partial(jax.jit, static_argnames=("kx", "ky"))
def turbo2d(x, wr, wi, kx, ky):
    return ops.spectral_layer_2d(x, wr, wi, (kx, ky), path="xla")


def run(quick: bool = False):
    print("# bench_e2e (paper Fig.14/19): name,us_per_call,derived")
    rng = np.random.default_rng(0)
    # --- 1D grid ---
    n = 256
    grid = [(16, 512), (32, 2048), (64, 8192), (128, 8192)]
    if quick:
        grid = grid[:2]
    speedups = []
    for h, bs in grid:
        k = n // 4
        b = max(1, bs // h)
        x = jnp.asarray(rng.normal(size=(b, h, n)), jnp.float32)
        wr = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        wi = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        t_base = time_fn(pl.baseline_staged, x, wr, wi, k)
        t_turbo = time_fn(pl.fused_full, x, wr, wi, k)
        s = t_base / t_turbo
        speedups.append(s)
        row(f"e2e1d_K{h}_BS{bs}", t_turbo, f"speedup={s:.2f}x")
    row("e2e1d_avg", 0.0,
        f"avg_speedup={np.mean(speedups):.2f}x max={np.max(speedups):.2f}x")

    # --- 2D grid ---
    nx = ny = 64 if quick else 128
    grid2 = [(16, 8), (32, 8), (64, 4)]
    if quick:
        grid2 = grid2[:1]
    speedups2 = []
    for h, b in grid2:
        kx, ky = nx // 4, ny // 4
        x = jnp.asarray(rng.normal(size=(b, h, nx, ny)), jnp.float32)
        wr = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        wi = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        t_base = time_fn(baseline2d, x, wr, wi, kx, ky)
        t_turbo = time_fn(turbo2d, x, wr, wi, kx, ky)
        s = t_base / t_turbo
        speedups2.append(s)
        row(f"e2e2d_K{h}_B{b}", t_turbo, f"speedup={s:.2f}x")
    row("e2e2d_avg", 0.0,
        f"avg_speedup={np.mean(speedups2):.2f}x max={np.max(speedups2):.2f}x")

    run_block(quick)
    run_tuned(quick)
    run_serve(quick)


def run_block(quick: bool = False):
    """Fused-block vs unfused-block row pair (PR 4): one whole 2D FNO
    block on the pallas path — the staged composition (fused spectral
    kernel + XLA bypass/bias/GELU tail) vs the single-pallas_call block.
    derived = modeled HBM bytes per forward + kernel-call counts; NOTE
    off-TPU the pallas kernels run in interpret mode so the wall-time
    ratio only validates the harness (the byte model carries the claim).
    """
    import dataclasses

    from repro.configs import get_config
    from repro.roofline.analysis import fno_model_bytes
    from repro.roofline.hlo_counter import (count_pallas_calls,
                                            jaxpr_primitive_counts)

    print("# bench_e2e fused-block rows: name,us_per_call,derived")
    rng = np.random.default_rng(1)
    b, h, n, k = (1, 16, 32, 8) if quick else (2, 32, 64, 16)
    x = jnp.asarray(rng.normal(size=(b, h, n, n)), jnp.float32)
    wr = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
    wi = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
    wb = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
    bias = jnp.asarray(rng.normal(size=(h,)) * 0.1, jnp.float32)

    @jax.jit
    def unfused(x, wr, wi, wb, bias):
        s = ops.spectral_layer_2d(x, wr, wi, (k, k), path="pallas")
        byp = jnp.einsum("oh,bhxy->boxy", wb, x)
        return jax.nn.gelu(s + byp + bias[None, :, None, None])

    @jax.jit
    def fused(x, wr, wi, wb, bias):
        return ops.fno_block_nd(x, wr, wi, wb, bias, (k, k), path="pallas",
                                variant="full")

    cfg = dataclasses.replace(
        get_config("fno2d", reduced=quick), hidden=h, spatial=(n, n),
        modes=(k, k), num_layers=1)
    # fno_model_bytes models a whole step; the benchmarked functions are
    # ONE bare block, so subtract the layer-independent io + lift/proj
    # traffic (the num_layers=0 evaluation) to get block-only bytes.
    overhead = fno_model_bytes(dataclasses.replace(cfg, num_layers=0), b,
                               training=False)
    times, bts = {}, {}
    for name, fn, fb in (("unfused", unfused, False), ("fused", fused, True)):
        times[name] = time_fn(fn, x, wr, wi, wb, bias, iters=5)
        bts[name] = fno_model_bytes(cfg, b, fuse_block=fb,
                                    training=False) - overhead
        n_pallas = count_pallas_calls(fn, x, wr, wi, wb, bias)
        # launch-level op count: pallas_call bodies NOT expanded, so the
        # unfused row carries the XLA tail (bypass GEMM/bias/sum/GELU)
        # the fused row folds into its single kernel
        n_ops = sum(jaxpr_primitive_counts(
            fn, x, wr, wi, wb, bias, into_kernels=False).values())
        row(f"block2d_{name}_H{h}N{n}", times[name],
            f"bytes={bts[name] / 2 ** 20:.2f}MiB pallas_calls={n_pallas} "
            f"launch_ops={n_ops}")
    row("block2d_fusion_gain", times["fused"],
        f"bytes_ratio={bts['fused'] / bts['unfused']:.3f}x "
        f"speedup={times['unfused'] / times['fused']:.2f}x")


def run_tuned(quick: bool = False):
    """Tuned vs default launch-plan row trios (ISSUE 7), ranks 1-3: the
    whole fused FNO block forward at the committed autotuned plan
    (``repro.tuning`` cache resolution, block_plan=None) against the
    static ``ops._BLOCK_DEFAULTS`` triple forced via ``block_plan=``.
    derived = the effective plans, each plan's VMEM launch estimate, the
    plan-invariant modeled HBM bytes, and the tuned/default parity
    max-|Δ| (must be float-noise). Off-TPU the kernels run in interpret
    mode, so wall time tracks grid-step count rather than MXU behavior —
    the VMEM estimates carry the feasibility claim (full-size 2D/3D fit
    the budget ONLY under tuned plans; the defaults are 2-9x over)."""
    import dataclasses

    from repro.analysis.vmem import launch_estimate
    from repro.configs import get_config
    from repro.kernels.ops import _BLOCK_DEFAULTS, _pick_block
    from repro.roofline.analysis import fno_model_bytes
    from repro.tuning import resolve_block_plan

    print("# bench_e2e tuned-plan rows: name,us_per_call,derived")
    rng = np.random.default_rng(3)
    b = 4 if quick else 8
    for arch in ("fno1d", "fno2d", "fno3d"):
        cfg = get_config(arch, reduced=True)
        r, h = cfg.ndim, cfg.hidden
        modes = tuple(cfg.modes)
        x = jnp.asarray(rng.normal(size=(b, h) + tuple(cfg.spatial)),
                        jnp.float32)
        wr = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        wi = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        wb = jnp.asarray(rng.normal(size=(h, h)) / h, jnp.float32)
        bias = jnp.asarray(rng.normal(size=(h,)) * 0.1, jnp.float32)

        tuned = resolve_block_plan(cfg, "block_fwd").triple
        dflt = _BLOCK_DEFAULTS[r]
        shapes = (h, tuple(cfg.spatial), modes,
                  cfg.weight_mode == "per_mode")
        hbm = fno_model_bytes(
            dataclasses.replace(cfg, num_layers=1), b, fuse_block=True,
            training=False)
        outs, times = {}, {}
        for name, plan in (("tuned", None), ("default", dflt)):
            fn = jax.jit(functools.partial(
                ops.fno_block_nd, modes=modes, path="pallas",
                variant="full", block_plan=plan))
            times[name] = time_fn(fn, x, wr, wi, wb, bias, iters=5)
            outs[name] = fn(x, wr, wi, wb, bias)
            triple = tuned if plan is None else plan
            eff = (_pick_block(b, triple[0]), _pick_block(h, triple[1]),
                   _pick_block(h, triple[2]))
            est = launch_estimate(shapes, "block_fwd", triple, batch=b)
            row(f"tuned_r{r}_{name}", times[name],
                f"plan={eff} vmem_est={est.total_bytes / 2**20:.2f}MiB "
                f"hbm_model={hbm / 2**20:.2f}MiB")
        err = float(jnp.max(jnp.abs(outs["tuned"] - outs["default"])))
        row(f"tuned_r{r}_gain", times["tuned"],
            f"speedup={times['default'] / times['tuned']:.2f}x "
            f"parity_max_err={err:.2e}")
        assert err < 1e-4, f"tuned/default parity broke at rank {r}: {err}"


def run_serve(quick: bool = False):
    """FNO serving throughput rows (ISSUE 5 + ISSUE 8): the batched serve
    step with the whole-block fusion on vs off, placed DP×TP over the
    local devices (DP shards the request batch, TP the hidden k-loop axis
    when it divides — docs/DESIGN.md §6), then the TP collective-layout
    pair — the scattered layout (interior psum_scatter emitting the next
    layer's hidden shard) vs the all-reduce-every-layer psum layout.
    derived = samples/s, the mesh grid, and `coll_bytes` — the modeled
    per-device ICI wire bytes of the TP collectives per forward
    (`roofline.analysis.fno_collective_bytes`); off-TPU the pallas kernels
    run in interpret mode and the collectives cross no real ICI, so the
    byte model carries the traffic claim (exactly 0.5x per interior layer)
    while the wall ratio only validates the harness."""
    import dataclasses

    from repro.configs import get_config
    from repro.core import fno as fno_mod
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_compat_mesh
    from repro.launch.serve_fno import _pick_tp
    from repro.roofline.analysis import fno_collective_bytes
    from repro.train import serve_fno_step as sfs

    print("# bench_e2e serving rows: name,us_per_call,derived")
    n_dev = jax.device_count()
    cfg0 = get_config("fno2d", reduced=True)
    tp = _pick_tp(n_dev, cfg0.hidden)  # the serving driver's own auto-pick
    dp = n_dev // tp
    mesh = make_compat_mesh((dp, tp), ("data", "model"))
    b = 4 if quick else 8
    x = jnp.asarray(np.random.default_rng(2).normal(
        size=(b, cfg0.in_channels) + tuple(cfg0.spatial)), jnp.float32)

    def serve_time(cfg):
        ctx = shd.make_context(cfg, mesh, kind="serve")
        params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
        # one full-bucket request per call — the server's own jit cache
        server = sfs.FNOServer(cfg, params, ctx=ctx, max_batch=b)
        return time_fn(server, x, iters=5)

    times = {}
    for name, fuse in (("unfused", False), ("fused", True)):
        cfg = dataclasses.replace(cfg0, path="pallas", fuse_block=fuse)
        times[name] = serve_time(cfg)
        cb = fno_collective_bytes(cfg, dp, tp, batch=b)
        row(f"serve2d_{name}_dp{dp}tp{tp}", times[name],
            f"samples_per_s={b / (times[name] * 1e-6):.1f} "
            f"coll_bytes={cb['total'] / 2**10:.1f}KiB")
    row("serve2d_fusion_gain", times["fused"],
        f"speedup={times['unfused'] / times['fused']:.2f}x grid=dp{dp}xtp{tp}")

    # TP collective-layout pair (ISSUE 8): fused serve step under the
    # scattered layout vs the legacy psum layout, same mesh. The modeled
    # interior-layer wire bytes halve under scatter; the final layer
    # always psums (the projection consumes the full hidden vector).
    lt, lb = {}, {}
    for layout in ("scatter", "psum"):
        cfg = dataclasses.replace(cfg0, path="pallas", fuse_block=True,
                                  tp_layout=layout)
        lt[layout] = serve_time(cfg)
        lb[layout] = fno_collective_bytes(cfg, dp, tp,
                                          scattered=layout == "scatter",
                                          batch=b)
        row(f"serve2d_fused_{layout}_dp{dp}tp{tp}", lt[layout],
            f"samples_per_s={b / (lt[layout] * 1e-6):.1f} "
            f"coll_bytes={lb[layout]['total'] / 2**10:.1f}KiB "
            f"interior_per_layer={lb[layout]['interior_per_layer'] / 2**10:.1f}KiB")
    ratio = (lb["scatter"]["interior_per_layer"]
             / lb["psum"]["interior_per_layer"]) if tp > 1 else 0.0
    row("serve2d_layout_gain", lt["scatter"],
        f"speedup={lt['psum'] / lt['scatter']:.2f}x "
        f"interior_bytes_ratio={ratio:.3f}x grid=dp{dp}xtp{tp}")

    run_replay(quick)


def run_replay(quick: bool = False):
    """Traffic-replay serving rows (ISSUE 10): the async continuous-
    batching tier (``train/serve_queue``) under a seeded Poisson-ish
    arrival schedule — p50/p99 enqueue→complete latency and queue-depth
    rows next to the throughput rows. The schedule is a pure function of
    its seed (no wall-clock randomness); the event loop runs on a virtual
    clock whose per-bucket service model is CALIBRATED from this host's
    measured fused serve step, and the arrival rate is set to ~1.2x the
    calibrated capacity so the queue actually builds depth on any
    machine. Admission/coalescing decisions are therefore deterministic
    given the calibration; absolute latencies are host latencies, same
    caveat as every wall-time row. Row schema: benchmarks/README.md."""
    import dataclasses

    from repro.configs import get_config
    from repro.core import fno as fno_mod
    from repro.train import serve_fno_step as sfs
    from repro.train import serve_queue as sq

    print("# bench_e2e replay rows: name,us_per_call,derived")
    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    max_batch = 4 if quick else 8
    server = sfs.FNOServer(cfg, params, max_batch=max_batch)
    steps = 2  # every request asks a 2-step device-resident rollout
    base = {}
    for b in server.buckets:
        xb = jnp.zeros((b, cfg.in_channels) + tuple(cfg.spatial),
                       jnp.float32)
        base[b] = time_fn(
            lambda xb=xb: server(xb, rollout_steps=steps), iters=3) * 1e-6
    service_model = lambda bucket, k: base[bucket]  # noqa: E731

    top = server.buckets[-1]
    mean_n = (1 + max_batch) / 2
    rate_hz = 1.2 * (top / base[top]) / mean_n  # ~1.2x calibrated capacity
    deadline_s = 20 * base[top]
    requests = 24 if quick else 64
    cbs = sq.ContinuousBatchingServer(
        server, queue_limit=2 * max_batch, coalesce_s=1.0 / rate_hz,
        clock=sq.VirtualClock(), service_model=service_model)
    sched = sq.poisson_schedule(7, requests, rate_hz=rate_hz,
                                max_n=max_batch, rollout_steps=steps,
                                deadline_s=deadline_s)
    rng = np.random.default_rng(7)
    xs = [jnp.asarray(rng.normal(
        size=(a.n, cfg.in_channels) + tuple(cfg.spatial)), jnp.float32)
        for a in sched]
    rep = cbs.replay(sched, lambda a, i: xs[i])
    s, lat, qd = rep["stats"], rep["latency"], rep["queue_depth"]
    row("serve2d_replay_lat", lat["p50"] * 1e6,
        f"p50_ms={lat['p50']*1e3:.2f} p99_ms={lat['p99']*1e3:.2f} "
        f"deadline_ms={deadline_s*1e3:.2f} completed={s['completed']} "
        f"rollout_steps={steps}")
    row("serve2d_replay_queue", 0.0,
        f"qdepth_p50={qd['p50']:.1f} qdepth_p99={qd['p99']:.1f} "
        f"qdepth_max={qd['max']:.0f} batches={s['batches']} "
        f"coalesced={s['coalesced']} shed={s['shed']} "
        f"deadline_exceeded={s['deadline_exceeded']}")
    tput = rep["served_samples"] / max(rep["makespan_s"], 1e-9)
    row("serve2d_replay_tput", 0.0,
        f"samples_per_s={tput:.1f} makespan_ms={rep['makespan_s']*1e3:.0f} "
        f"offered={s['offered']} accepted={s['accepted']}")


if __name__ == "__main__":
    run()
