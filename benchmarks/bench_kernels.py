"""Paper §3.1/3.2 — 'custom FFT and GEMM kernels match the vendor
libraries'. CPU analogue: the truncated-DFT matmul formulation vs the
vendor FFT (pocketfft via jnp.fft) + slice, and XLA CGEMM vs the 4-matmul
form; correctness deltas + wall time. derived = speedup + max |err|."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn
from repro.core import spectral as sp


@functools.partial(jax.jit, static_argnames=("k",))
def vendor_fft_trunc(x, k):
    xf = jnp.fft.rfft(x, axis=-1)
    return xf.real[..., :k].copy(), xf.imag[..., :k].copy()


@functools.partial(jax.jit, static_argnames=("k",))
def custom_dft_trunc(x, k):
    return sp.truncated_rdft(x, k)


def run(quick: bool = False):
    print("# bench_kernels (paper §3.1-3.2): name,us_per_call,derived")
    rng = np.random.default_rng(0)
    cases = [(256, 64, 4096), (256, 128, 4096), (128, 32, 8192)]
    if quick:
        cases = cases[:1]
    for n, k, rows_ in cases:
        x = jnp.asarray(rng.normal(size=(rows_, n)), jnp.float32)
        t_vendor = time_fn(vendor_fft_trunc, x, k)
        t_custom = time_fn(custom_dft_trunc, x, k)
        vr, vi = vendor_fft_trunc(x, k)
        cr, ci = custom_dft_trunc(x, k)
        err = max(float(jnp.abs(vr - cr).max()), float(jnp.abs(vi - ci).max()))
        row(f"trunc_fft_n{n}_k{k}", t_custom,
            f"vs_vendor={t_vendor / t_custom:.2f}x max_err={err:.1e}")


if __name__ == "__main__":
    run()
