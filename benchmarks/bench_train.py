"""Training-step benchmark: staged-XLA vs fused Pallas forward+backward.

The TurboFNO claim extended to training — with the custom_vjp in place the
backward pass is itself a fused DFT→CGEMM→iDFT pipeline (input cotangent)
plus a fused rank-reduction kernel (weight cotangent), so a whole
value_and_grad step runs without the staged path's intermediate HBM
round-trips.

Two tiers:
  * layer: value_and_grad through a single spectral layer, 1D and 2D;
  * step:  a full FNO AdamW train step (reduced fno2d config).

derived = fused-path speedup over the staged-XLA step. NOTE: off-TPU the
pallas kernels run in interpret mode, so absolute numbers (and speedups
< 1) on CPU only validate the harness; TPU runs report the real ratio.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn


def _layer_cases(quick: bool):
    cases_1d = [(4, 32, 32, 256, 64)]  # B,H,O,N,K — paper N=256, 50% trunc
    cases_2d = [(2, 16, 16, 64, 64, 16, 16)]
    if not quick:
        cases_1d.append((8, 64, 64, 256, 64))
        cases_2d.append((2, 32, 32, 64, 64, 16, 16))
    return cases_1d, cases_2d


def run(quick: bool = False):
    from repro.kernels import ops

    print("# bench_train (fwd+bwd): name,us_per_call,derived")
    rng = np.random.default_rng(0)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    cases_1d, cases_2d = _layer_cases(quick)

    def vag(layer_fn):
        loss = lambda x, wr, wi: jnp.sum(layer_fn(x, wr, wi) ** 2)
        return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))

    for b, h, o, n, k in cases_1d:
        x, wr, wi = mk(b, h, n), mk(o, h) / h, mk(o, h) / h
        times = {}
        for path in ("xla", "pallas"):
            f = vag(lambda x, wr, wi, p=path: ops.spectral_layer_1d(
                x, wr, wi, k, path=p))
            times[path] = time_fn(f, x, wr, wi, iters=5)
            row(f"grad1d_{path}_B{b}H{h}N{n}K{k}", times[path], "")
        row(f"grad1d_speedup_B{b}H{h}N{n}K{k}", times["pallas"],
            f"speedup={times['xla'] / times['pallas']:.2f}x")

    for b, h, o, nx, ny, kx, ky in cases_2d:
        x, wr, wi = mk(b, h, nx, ny), mk(o, h) / h, mk(o, h) / h
        times = {}
        for path in ("xla", "pallas"):
            f = vag(lambda x, wr, wi, p=path: ops.spectral_layer_2d(
                x, wr, wi, (kx, ky), path=p))
            times[path] = time_fn(f, x, wr, wi, iters=5)
            row(f"grad2d_{path}_B{b}H{h}XY{nx}K{kx}", times[path], "")
        row(f"grad2d_speedup_B{b}H{h}XY{nx}K{kx}", times["pallas"],
            f"speedup={times['xla'] / times['pallas']:.2f}x")

    # full train step on the reduced 2D config
    from repro.configs import get_config
    from repro.core import fno as fno_mod
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.train_step import make_train_step

    cfg = get_config("fno2d", reduced=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=constant(1e-3))
    batch = {"x": mk(4, cfg.in_channels, *cfg.spatial),
             "y": mk(4, cfg.out_channels, *cfg.spatial)}
    times = {}
    for path in ("xla", "pallas"):
        step = jax.jit(make_train_step(cfg, opt, fno_path=path))
        state = opt.init(params)
        times[path] = time_fn(step, params, state, batch, iters=3)
        row(f"train_step_{path}_{cfg.name}", times[path], "")
    row(f"train_step_speedup_{cfg.name}", times["pallas"],
        f"speedup={times['xla'] / times['pallas']:.2f}x")


if __name__ == "__main__":
    run()
