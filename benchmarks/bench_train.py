"""Training-step benchmark: staged-XLA vs fused Pallas, rank sweep 1D/2D/3D.

The TurboFNO claim extended to training — with the custom_vjp in place the
backward pass is itself a fused DFT→CGEMM→iDFT pipeline (input cotangent)
plus a fused rank-reduction kernel (weight cotangent), so a whole
value_and_grad step runs without the staged path's intermediate HBM
round-trips.

Three tiers:
  * fwd:   forward-only spectral layer, every rank (1D/2D/3D) — the
    rank-sweep rows that track the 3D path in the perf trajectory JSON;
  * layer: value_and_grad through a single spectral layer, every rank;
  * step:  a full FNO AdamW train step (reduced fno2d config), plus an
    f32-vs-bf16 PrecisionPolicy row pair whose `derived` column reports
    the modeled HBM bytes per step (roofline.fno_model_bytes).

derived = fused-path speedup over the staged-XLA step. NOTE: off-TPU the
pallas kernels run in interpret mode, so absolute numbers (and speedups
< 1) on CPU only validate the harness; TPU runs report the real ratio.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_fn

# Per-rank layer cases: (B, H, O, spatial, modes). 1D/2D keep the paper's
# sizes (N=256 @ 50% truncation; 64² @ 25%); 3D is the Navier–Stokes-class
# grid at benchmark-friendly reduced extents.
_CASES = {
    1: [(4, 32, 32, (256,), (64,))],
    2: [(2, 16, 16, (64, 64), (16, 16))],
    3: [(1, 8, 8, (16, 16, 16), (4, 4, 4))],
}
_CASES_SLOW = {
    1: [(8, 64, 64, (256,), (64,))],
    2: [(2, 32, 32, (64, 64), (16, 16))],
    3: [(1, 16, 16, (32, 32, 32), (8, 8, 8))],
}

_LAYERS = {1: "spectral_layer_1d", 2: "spectral_layer_2d",
           3: "spectral_layer_3d"}


def _layer_fn(ops, rank: int, modes, path: str):
    fn = getattr(ops, _LAYERS[rank])
    m = modes[0] if rank == 1 else tuple(modes)
    return lambda x, wr, wi: fn(x, wr, wi, m, path=path)


def _tag(rank: int, b: int, h: int, spatial) -> str:
    return f"{rank}d_B{b}H{h}N{'x'.join(map(str, spatial))}"


def run(quick: bool = False, ranks: Sequence[int] = (1, 2, 3)):
    from repro.kernels import ops

    print("# bench_train (rank sweep, fwd and fwd+bwd): "
          "name,us_per_call,derived")
    rng = np.random.default_rng(0)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)

    def vag(layer_fn):
        loss = lambda x, wr, wi: jnp.sum(layer_fn(x, wr, wi) ** 2)
        return jax.jit(jax.value_and_grad(loss, argnums=(0, 1, 2)))

    for rank in ranks:
        cases = list(_CASES[rank])
        if not quick:
            cases += _CASES_SLOW[rank]
        for b, h, o, spatial, modes in cases:
            x = mk(b, h, *spatial)
            wr, wi = mk(o, h) / h, mk(o, h) / h
            tag = _tag(rank, b, h, spatial)
            # forward-only sweep
            times = {}
            for path in ("xla", "pallas"):
                f = jax.jit(_layer_fn(ops, rank, modes, path))
                times[path] = time_fn(f, x, wr, wi, iters=5)
                row(f"fwd{tag}_{path}", times[path], "")
            row(f"fwd{tag}_speedup", times["pallas"],
                f"speedup={times['xla'] / times['pallas']:.2f}x")
            # fwd+bwd sweep
            times = {}
            for path in ("xla", "pallas"):
                f = vag(_layer_fn(ops, rank, modes, path))
                times[path] = time_fn(f, x, wr, wi, iters=5)
                row(f"grad{tag}_{path}", times[path], "")
            row(f"grad{tag}_speedup", times["pallas"],
                f"speedup={times['xla'] / times['pallas']:.2f}x")

    # full train step on the reduced 2D config
    from repro.configs import get_config
    from repro.configs.fno import with_precision
    from repro.core import fno as fno_mod
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.roofline.analysis import fno_model_bytes
    from repro.train.train_step import make_train_step

    cfg = get_config("fno2d", reduced=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=constant(1e-3))
    batch = {"x": mk(4, cfg.in_channels, *cfg.spatial),
             "y": mk(4, cfg.out_channels, *cfg.spatial)}
    times = {}
    for path in ("xla", "pallas"):
        step = jax.jit(make_train_step(cfg, opt, fno_path=path))
        state = opt.init(params)
        times[path] = time_fn(step, params, state, batch, iters=3)
        row(f"train_step_{path}_{cfg.name}", times[path], "")
    row(f"train_step_speedup_{cfg.name}", times["pallas"],
        f"speedup={times['xla'] / times['pallas']:.2f}x")

    # dtype column: the same fused train step under the f32 vs bf16
    # PrecisionPolicy. `derived` carries the modeled HBM bytes per step
    # (roofline.fno_model_bytes) — the bf16 row shows the traffic
    # reduction that compounds with the fusion win (TurboFNO's
    # memory-bound argument); wall-clock off-TPU is interpret-mode
    # harness validation only.
    bts = {"f32": fno_model_bytes(cfg, batch["x"].shape[0])}
    # the f32 policy is the default config — reuse the timing from above
    row(f"train_step_pallas_{cfg.name}_f32", times["pallas"],
        f"bytes/step={bts['f32'] / 2 ** 20:.2f}MiB")
    bcfg = with_precision(cfg, "bf16")
    bparams = fno_mod.init_fno(jax.random.PRNGKey(0), bcfg)
    step = jax.jit(make_train_step(bcfg, opt, fno_path="pallas"))
    t = time_fn(step, bparams, opt.init(bparams), batch, iters=3)
    bts["bf16"] = fno_model_bytes(bcfg, batch["x"].shape[0])
    row(f"train_step_pallas_{cfg.name}_bf16", t,
        f"bytes/step={bts['bf16'] / 2 ** 20:.2f}MiB")
    row(f"train_step_bytes_reduction_{cfg.name}", 0.0,
        f"bf16/f32={bts['bf16'] / bts['f32']:.3f}x")


if __name__ == "__main__":
    run()
