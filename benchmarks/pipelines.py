"""The measured pipelines for the fusion benchmark family.

baseline_staged  — "PyTorch/cuFFT+cuBLAS" analogue: every stage is its own
                   jit'd call with a device round-trip between stages
                   (full-spectrum FFT, separate truncation copy, CGEMM,
                   separate zero-pad copy, iFFT).
fft_opt          — TurboFNO's FFT-level optimizations only (built-in
                   truncation/zero-pad/pruning via the truncated-DFT
                   formulation) but stages still separate (paper Fig.10/15).
fused_fgemm      — FFT fused into the CGEMM (one jit), iFFT separate
                   (paper Fig.11/16).
fused_gemmi      — FFT separate, CGEMM+iFFT fused (paper Fig.12/17).
fused_full       — single fully fused program (paper Fig.13/18); the
                   `pallas` flavor runs the actual fused kernel.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import spectral as sp
from repro.kernels import ops


# -- individual stages (jit'd separately => materialized between) -----------
@jax.jit
def _full_rfft(x):
    xf = jnp.fft.rfft(x, axis=-1)
    return xf.real, xf.imag


@functools.partial(jax.jit, static_argnames=("k",))
def _truncate(xr, xi, k):
    return xr[..., :k].copy(), xi[..., :k].copy()


@jax.jit
def _cgemm(wr, wi, xr, xi):
    yr = jnp.einsum("oh,bhm->bom", wr, xr) - jnp.einsum("oh,bhm->bom", wi, xi)
    yi = jnp.einsum("oh,bhm->bom", wr, xi) + jnp.einsum("oh,bhm->bom", wi, xr)
    return yr, yi


@functools.partial(jax.jit, static_argnames=("n",))
def _zero_pad(yr, yi, n):
    pad = [(0, 0), (0, 0), (0, n // 2 + 1 - yr.shape[-1])]
    return jnp.pad(yr, pad), jnp.pad(yi, pad)


@functools.partial(jax.jit, static_argnames=("n",))
def _irfft(yr, yi, n):
    return jnp.fft.irfft(yr + 1j * yi, n=n, axis=-1)


@functools.partial(jax.jit, static_argnames=("k",))
def _trunc_rdft(x, k):
    return sp.truncated_rdft(x, k)


@functools.partial(jax.jit, static_argnames=("n",))
def _pad_irdft(yr, yi, n):
    return sp.padded_irdft(yr, yi, n)


@functools.partial(jax.jit, static_argnames=("k",))
def _fused_dft_gemm(x, wr, wi, k):
    xr, xi = sp.truncated_rdft(x, k)
    yr = jnp.einsum("oh,bhm->bom", wr, xr) - jnp.einsum("oh,bhm->bom", wi, xi)
    yi = jnp.einsum("oh,bhm->bom", wr, xi) + jnp.einsum("oh,bhm->bom", wi, xr)
    return yr, yi


@functools.partial(jax.jit, static_argnames=("n",))
def _fused_gemm_idft(xr, xi, wr, wi, n):
    yr = jnp.einsum("oh,bhm->bom", wr, xr) - jnp.einsum("oh,bhm->bom", wi, xi)
    yi = jnp.einsum("oh,bhm->bom", wr, xi) + jnp.einsum("oh,bhm->bom", wi, xr)
    return sp.padded_irdft(yr, yi, n)


@functools.partial(jax.jit, static_argnames=("k",))
def _fused_full(x, wr, wi, k):
    return ops.spectral_layer_1d(x, wr, wi, k, path="xla")


# -- pipelines ---------------------------------------------------------------
def baseline_staged(x, wr, wi, k):
    n = x.shape[-1]
    fr, fi = _full_rfft(x)
    tr, ti = _truncate(fr, fi, k)
    yr, yi = _cgemm(wr, wi, tr, ti)
    pr, pi = _zero_pad(yr, yi, n)
    return _irfft(pr, pi, n)


def fft_opt(x, wr, wi, k):
    n = x.shape[-1]
    tr, ti = _trunc_rdft(x, k)
    yr, yi = _cgemm(wr, wi, tr, ti)
    return _pad_irdft(yr, yi, n)


def fused_fgemm(x, wr, wi, k):
    n = x.shape[-1]
    yr, yi = _fused_dft_gemm(x, wr, wi, k)
    return _pad_irdft(yr, yi, n)


def fused_gemmi(x, wr, wi, k):
    tr, ti = _trunc_rdft(x, k)
    return _fused_gemm_idft(tr, ti, wr, wi, x.shape[-1])


def fused_full(x, wr, wi, k):
    return _fused_full(x, wr, wi, k)


# -- derived global-memory traffic model (paper's motivation) ---------------
def traffic_bytes(b, h, o, n, k, pipeline: str, dtype_bytes: int = 4) -> int:
    """HBM bytes moved, per the paper's staged-vs-fused accounting."""
    nf = n // 2 + 1
    rd = lambda *sizes: sum(sizes)
    c = 2  # complex = 2 planes
    x_ = b * h * n
    Xf = b * h * nf * c
    Xt = b * h * k * c
    Y = b * o * k * c
    Yp = b * o * nf * c
    y = b * o * n
    if pipeline == "baseline":
        total = (x_ + Xf) + (Xf + Xt) + (Xt + Y) + (Y + Yp) + (Yp + y)
    elif pipeline == "fft_opt":  # built-in truncation / zero-pad
        total = (x_ + Xt) + (Xt + Y) + (Y + y)
    elif pipeline == "fused_fgemm":
        total = (x_ + Y) + (Y + y)
    elif pipeline == "fused_gemmi":
        total = (x_ + Xt) + (Xt + y)
    else:  # fused_full
        total = x_ + y
    return total * dtype_bytes
