"""Block-size autotuner tests (ISSUE 7): key schema, cache round-trip and
staleness lint, resolver precedence, VMEM feasibility of every runnable
cell under the committed cache, block-clamp regressions, and numerical
parity of tuned vs default launch plans (forward AND grads, ranks 1-3)."""
import jax
import jax.numpy as jnp
import pytest

from repro.analysis import vmem
from repro.configs import FNO_IDS, get_config
from repro.configs.base import PrecisionPolicy
from repro.configs.fno import with_block_plan
from repro.kernels import ops
from repro.tuning import (autotune, plans, resolve_block_plan,
                          resolve_launch_plans, store)
from repro.tuning.plans import LaunchPlans


# ---------------------------------------------------------------------------
# key schema
# ---------------------------------------------------------------------------
def test_plan_key_roundtrip_and_variant_normalization():
    klass = plans.shape_class(64, 64, (128, 128), (32, 32))
    assert klass == "h64-s128x128-m32x32"
    for launch in plans.LAUNCH_KINDS:
        key = plans.plan_key(2, klass, "shared", "bf16", launch)
        parsed = plans.parse_key(key)
        assert parsed["launch"] == launch
        # backward launches key as "full"; core is the only "partial"
        assert parsed["variant"] == ("partial" if launch == "core"
                                     else "full")


def test_shape_class_distinguishes_cells():
    # distinct (hidden | spatial | modes | out) => distinct keys; batch
    # never participates
    a = plans.shape_class(64, 64, (128,), (32,))
    assert plans.shape_class(128, 128, (128,), (32,)) != a
    assert plans.shape_class(64, 64, (256,), (32,)) != a
    assert plans.shape_class(64, 64, (128,), (64,)) != a
    assert "o32" in plans.shape_class(64, 32, (128,), (32,))
    # pow2 bucketing: nearby shapes transfer
    assert plans.shape_class(60, 60, (100,), (30,)) == a


def test_parse_key_rejects_defects():
    ok = plans.plan_key(2, "h64-s128x128-m32x32", "shared", "f32", "wgrad")
    plans.parse_key(ok)
    for bad in ("r2/only/four/segs",
                "r4/h64-s128-m32/shared/full/f32/block_fwd",
                "r2/h64-s128-m32/diag/full/f32/block_fwd",
                "r2/h64-s128-m32/shared/full/f32/warp",
                "r2/h64-s128-m32/shared/partial/f32/block_fwd"):
        with pytest.raises(ValueError):
            plans.parse_key(bad)


# ---------------------------------------------------------------------------
# cache store
# ---------------------------------------------------------------------------
def _entry(bb, bo, bh, probe=None):
    return {"bb": bb, "bo": bo, "bh": bh,
            "probe": probe or {"batch": 8, "hidden": 16, "spatial": [64],
                               "modes": [16]}}


def test_cache_roundtrip_and_lint_clean(tmp_path):
    path = str(tmp_path / "blocks.json")
    key = plans.plan_key(1, plans.shape_class(16, 16, (64,), (16,)),
                         "shared", "f32", "block_fwd")
    store.save_cache({key: _entry(8, 16, 16)}, path=path)
    assert store.lookup(key, path) == (8, 16, 16)
    assert store.lookup("r1/h16-s64-m16/shared/full/bf16/block_fwd",
                        path) is None  # distinct dtype key: miss
    assert [f for f in store.check_tuning_cache(path)
            if f.severity == "error"] == []


def test_cache_staleness_lint_fires(tmp_path):
    key = plans.plan_key(1, plans.shape_class(16, 16, (64,), (16,)),
                         "shared", "f32", "block_fwd")

    # engine signature mismatch
    p1 = str(tmp_path / "stale_sig.json")
    store.save_cache({key: _entry(8, 16, 16)},
                     meta={"engine_signature": "fnond-v0:obsolete"}, path=p1)
    fs = store.check_tuning_cache(p1)
    assert any("signature mismatch" in f.message for f in fs)

    # unparseable key + non-positive triple + missing probe
    p2 = str(tmp_path / "broken.json")
    store.save_cache({
        "not/a/key": _entry(1, 1, 1),
        key: {"bb": 0, "bo": 16, "bh": 16, "probe": {}},
    }, path=p2)
    msgs = " | ".join(f.message for f in store.check_tuning_cache(p2))
    assert "unparseable key" in msgs and "positive integer" in msgs

    # stale winner: recorded probe no longer fits under the estimator
    p3 = str(tmp_path / "stale_win.json")
    big = plans.plan_key(3, plans.shape_class(32, 32, (64, 64, 64),
                                              (16, 16, 16)),
                         "shared", "f32", "block_fwd")
    store.save_cache({big: _entry(8, 128, 128, probe={
        "batch": 8, "hidden": 32, "spatial": [64, 64, 64],
        "modes": [16, 16, 16]})}, path=p3)
    fs = store.check_tuning_cache(p3)
    assert any("stale winner" in f.message for f in fs)

    # absent file: warn, not error
    fs = store.check_tuning_cache(str(tmp_path / "nope.json"))
    assert len(fs) == 1 and fs[0].severity == "warn"


def test_committed_cache_is_fresh():
    fs = [f for f in store.check_tuning_cache() if f.severity == "error"]
    assert fs == [], fs
    assert store.load_cache()["entries"], "committed cache must be non-empty"


# ---------------------------------------------------------------------------
# resolver precedence
# ---------------------------------------------------------------------------
def test_resolver_precedence(tmp_path, monkeypatch):
    cfg = get_config("fno2d", reduced=True)
    # cache hit
    p = resolve_block_plan(cfg, "block_fwd")
    assert p.source == "cache" and all(v > 0 for v in p.triple)
    # explicit override beats the cache, component-wise
    p2 = resolve_block_plan(cfg, "block_fwd", override=(4, 0, 0))
    assert p2.source == "override"
    assert p2.bb == 4 and (p2.bo, p2.bh) == (p.bo, p.bh)
    # cfg.block_plan participates as the override
    p3 = resolve_block_plan(with_block_plan(cfg, 2, 0, 8), "block_fwd")
    assert (p3.bb, p3.bh) == (2, 8) and p3.bo == p.bo
    # no cache -> static defaults
    monkeypatch.setattr(store, "load_cache",
                        lambda path=None: {"meta": {}, "entries": {}})
    p4 = resolve_block_plan(cfg, "block_fwd")
    assert p4.source == "default"
    assert p4.triple == ops._BLOCK_DEFAULTS[cfg.ndim]


def test_rank1_core_aliases_block_fwd():
    cfg = get_config("fno1d", reduced=True)
    lp = resolve_launch_plans(1, hidden=cfg.hidden,
                              spatial=tuple(cfg.spatial),
                              modes=tuple(cfg.modes))
    assert lp.core == lp.fwd
    assert resolve_block_plan(cfg, "core").key.endswith("block_fwd")


def test_serve_batch_block_routes_through_resolver():
    from repro.train.serve_fno_step import batch_block

    for arch in FNO_IDS:
        cfg = get_config(arch, reduced=True)
        assert batch_block(cfg) == resolve_block_plan(cfg, "block_fwd").bb


def test_serve_quantum_validates_against_tuned_plan():
    # ISSUE 10: the bucket-ladder quantum must stay a multiple of the
    # TUNED plan's batch block, not the static default — a retune that
    # changes bb can never silently misalign an explicit ladder.
    from repro.tuning import serve_quantum

    for arch in FNO_IDS:
        cfg = get_config(arch, reduced=True)
        bb = resolve_block_plan(cfg, "block_fwd").bb
        assert serve_quantum(cfg) == bb  # None -> the tuned bb itself
        assert serve_quantum(cfg, bb) == bb
        assert serve_quantum(cfg, 3 * bb) == 3 * bb  # e.g. bb x dp shards
        for bad in (bb + 1, -bb, 0):
            with pytest.raises(ValueError, match="tuned batch block"):
                serve_quantum(cfg, bad)


def test_serve_quantum_follows_block_plan_override():
    # A pinned cfg-level launch plan changes the resolved bb, and the
    # quantum validation must follow it (the override wins over cache).
    from repro.tuning import serve_quantum

    cfg = get_config("fno2d", reduced=True)
    base = resolve_block_plan(cfg, "block_fwd").bb
    pinned = with_block_plan(cfg, 2 * base, 0, 0)
    assert resolve_block_plan(pinned, "block_fwd").bb == 2 * base
    assert serve_quantum(pinned) == 2 * base
    with pytest.raises(ValueError, match="tuned batch block"):
        serve_quantum(pinned, base)  # a multiple of the OLD bb only


def test_fno_server_rejects_misaligned_quantum():
    # The server constructor routes through serve_quantum, so a bad
    # explicit quantum fails loudly at build time — not as internal
    # padding on the first request.
    from repro.core import fno as fno_mod
    from repro.train.serve_fno_step import FNOServer
    import dataclasses as dc

    cfg = dc.replace(get_config("fno2d", reduced=True), path="pallas",
                     fuse_block=True)
    params = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        jax.eval_shape(lambda: fno_mod.init_fno(jax.random.PRNGKey(0),
                                                cfg)))
    bb = resolve_block_plan(cfg, "block_fwd").bb
    with pytest.raises(ValueError, match="tuned batch block"):
        FNOServer(cfg, params, max_batch=2 * bb, quantum=bb + 1)
    server = FNOServer(cfg, params, max_batch=2 * bb, quantum=bb)
    assert server.buckets[0] == bb  # ladder starts at the tuned quantum
    assert all(b % bb == 0 for b in server.buckets)


# ---------------------------------------------------------------------------
# feasibility: every runnable cell resolves budget-fitting plans
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", ["f32", "bf16"])
@pytest.mark.parametrize("reduced", [True, False])
def test_all_cells_resolve_feasible_plans(dtype, reduced):
    pol = PrecisionPolicy.from_name(dtype)
    for arch in FNO_IDS:
        cfg = get_config(arch, reduced=reduced)
        for variant in ("full", "partial"):
            ests = vmem.block_launch_estimates(cfg, variant=variant,
                                               policy=pol)
            for name, e in ests.items():
                assert e.total_bytes <= vmem.VMEM_BUDGET_BYTES, (
                    f"{arch} reduced={reduced} {dtype} {variant} {name}: "
                    f"{e.total_bytes / 2**20:.1f} MiB over budget")


def test_autotune_smoke_covers_reduced_cells(tmp_path):
    path, entries = autotune.tune(measure="none", smoke=True,
                                  out=str(tmp_path / "b.json"),
                                  log=lambda *a: None)
    assert entries
    for key in entries:
        plans.parse_key(key)  # every key well-formed
    assert [f for f in store.check_tuning_cache(path)
            if f.severity == "error"] == []


# ---------------------------------------------------------------------------
# _pick_block clamp regressions (odd extents must not explode padding)
# ---------------------------------------------------------------------------
def test_pick_block_minimizes_pad_waste():
    assert ops._pick_block(129, 128) == 8      # pads to 136, not 256
    assert ops._pick_block(192, 128) == 96     # exact multiple, zero waste
    assert ops._pick_block(64, 128) == 64
    assert ops._pick_block(4, 128) == 8        # tiny dims keep one block
    assert ops._pick_block(1, 2) == 1          # no padding a singleton
    assert ops._pick_block(8, 2) == 2          # explicit small pref wins
    for dim, pref in ((129, 128), (65, 64), (33, 32), (7, 8)):
        b = ops._pick_block(dim, pref)
        padded = -dim % b + dim
        assert padded - dim < dim, (dim, pref, b)  # waste strictly < 100%


# ---------------------------------------------------------------------------
# parity: differing launch plans change nothing numerically
# ---------------------------------------------------------------------------
def _tiny(rank, seed=0):
    h, n, m = 8, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    x = jax.random.normal(ks[0], (2, h) + (n,) * rank, jnp.float32)
    wr = 0.1 * jax.random.normal(ks[1], (h, h), jnp.float32)
    wi = 0.1 * jax.random.normal(ks[2], (h, h), jnp.float32)
    wb = 0.1 * jax.random.normal(ks[3], (h, h), jnp.float32)
    bias = 0.1 * jax.random.normal(ks[4], (h,), jnp.float32)
    return x, wr, wi, wb, bias, (m,) * rank


@pytest.mark.parametrize("rank", [1, 2, 3])
def test_block_plan_parity_fwd_and_grads(rank):
    x, wr, wi, wb, bias, modes = _tiny(rank)

    def run(block_plan):
        def loss(p):
            y = ops.fno_block_nd(x, p["wr"], p["wi"], p["wb"], p["b"],
                                 modes, path="pallas", interpret=True,
                                 block_plan=block_plan)
            return jnp.sum(y ** 2), y
        (l, y), g = jax.value_and_grad(loss, has_aux=True)(
            {"wr": wr, "wi": wi, "wb": wb, "b": bias})
        return y, g

    def rel(a, b):  # block size changes accumulation order, not math
        return jnp.max(jnp.abs(a - b)) / (jnp.max(jnp.abs(b)) + 1e-30)

    y0, g0 = run(None)            # tuned-cache resolution
    y1, g1 = run((1, 4, 4))       # deliberately different plan
    assert rel(y0, y1) < 1e-5
    for k in g0:
        assert rel(g0[k], g1[k]) < 1e-5, k


def test_tuned_vs_default_parity_reduced_2d():
    cfg = get_config("fno2d", reduced=True)
    x, wr, wi, wb, bias, _ = _tiny(2, seed=1)
    modes = (4, 4)
    y_tuned = ops.fno_block_nd(x, wr, wi, wb, bias, modes, path="pallas",
                               interpret=True)
    dflt = ops._BLOCK_DEFAULTS[cfg.ndim]
    y_dflt = ops.fno_block_nd(x, wr, wi, wb, bias, modes, path="pallas",
                              interpret=True, block_plan=dflt)
    assert jnp.max(jnp.abs(y_tuned - y_dflt)) < 1e-5


# ---------------------------------------------------------------------------
# custom_vjp plumbing: LaunchPlans is hashable and jit-cache friendly
# ---------------------------------------------------------------------------
def test_launch_plans_hashable_and_override():
    lp = LaunchPlans.uniform((2, 128, 32))
    assert hash(lp) == hash(LaunchPlans.uniform((2, 128, 32)))
    ov = lp.with_override(bb=4)
    assert ov.fwd == (4, 128, 32) and ov.wgrad == (4, 128, 32)
    assert lp.with_override() is lp
    assert lp.for_launch("gz_recompute") == (2, 128, 32)
