"""Blocked CGEMM Pallas kernel vs 4-real-matmul oracle."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as ref_k

CASES = [
    (32, 16, 24),
    (128, 128, 128),
    (37, 19, 23),  # ragged (padding path)
    (256, 8, 64),  # tall-skinny, the paper's FNO regime
    (130, 257, 129),  # just past block boundaries
]


@pytest.mark.parametrize("m,k,n", CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_cgemm(m, k, n, dtype):
    rng = np.random.default_rng(m * 31 + n)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), dtype)
    ar, ai, br, bi = mk(m, k), mk(m, k), mk(k, n), mk(k, n)
    cr, ci = ops.cgemm(ar, ai, br, bi, path="pallas")
    rr, ri = ref_k.ref_cgemm(ar.astype(jnp.float32), ai.astype(jnp.float32),
                             br.astype(jnp.float32), bi.astype(jnp.float32))
    tol = dict(rtol=1e-4, atol=1e-3) if dtype == jnp.float32 else \
        dict(rtol=0.05, atol=0.5)
    np.testing.assert_allclose(np.asarray(cr, np.float32), rr, **tol)
    np.testing.assert_allclose(np.asarray(ci, np.float32), ri, **tol)
