"""The paper's contribution: fused FFT->CGEMM->iFFT kernels vs the staged
jnp.fft oracle — 1D and 2D, shared and per-mode weights, partial (paper-
faithful) and full (beyond-paper) fusion, shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as ref_k

TOL32 = dict(rtol=2e-4, atol=2e-4)


def _mk(rng, *s, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(scale * rng.normal(size=s), dtype)


CASES_1D = [
    # B, H, O, N, K
    (4, 24, 16, 64, 17),
    (2, 64, 64, 256, 64),  # paper's FFT size / 50% truncation (Table 1)
    (1, 8, 8, 128, 32),  # paper's 25% truncation
    (3, 16, 32, 128, 65),
]


@pytest.mark.parametrize("b,h,o,n,k", CASES_1D)
@pytest.mark.parametrize("weight_mode", ["shared", "per_mode"])
def test_fused_fno1d(b, h, o, n, k, weight_mode):
    rng = np.random.default_rng(b * 7 + k)
    x = _mk(rng, b, h, n)
    wshape = (o, h) if weight_mode == "shared" else (o, h, k)
    wr = _mk(rng, *wshape, scale=1.0 / h)
    wi = _mk(rng, *wshape, scale=1.0 / h)
    y = ops.spectral_layer_1d(x, wr, wi, k, path="pallas")
    yref = ref_k.ref_fno1d(x, wr, wi, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), **TOL32)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_fused_fno1d_bf16(dtype):
    rng = np.random.default_rng(5)
    x = _mk(rng, 2, 16, 64, dtype=dtype)
    wr = _mk(rng, 8, 16, dtype=dtype, scale=1 / 16)
    wi = _mk(rng, 8, 16, dtype=dtype, scale=1 / 16)
    y = ops.spectral_layer_1d(x, wr, wi, 16, path="pallas")
    yref = ref_k.ref_fno1d(x.astype(jnp.float32), wr.astype(jnp.float32),
                           wi.astype(jnp.float32), 16)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yref),
                               rtol=0.05, atol=0.05)


CASES_2D = [
    # B, H, O, X, Y, KX, KY
    (2, 12, 8, 32, 32, 9, 9),
    (1, 16, 16, 64, 64, 16, 16),  # 50% per-axis truncation (paper 2D)
    (2, 8, 8, 32, 64, 8, 17),
]


@pytest.mark.parametrize("b,h,o,x_,y_,kx,ky", CASES_2D)
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_fused_fno2d_shared(b, h, o, x_, y_, kx, ky, variant):
    rng = np.random.default_rng(x_ + ky)
    x = _mk(rng, b, h, x_, y_)
    wr = _mk(rng, o, h, scale=1.0 / h)
    wi = _mk(rng, o, h, scale=1.0 / h)
    y = ops.spectral_layer_2d(x, wr, wi, (kx, ky), path="pallas",
                              variant=variant)
    yref = ref_k.ref_fno2d(x, wr, wi, (kx, ky))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), **TOL32)


@pytest.mark.parametrize("b,h,o,x_,y_,kx,ky", CASES_2D[:2])
def test_fused_fno2d_permode(b, h, o, x_, y_, kx, ky):
    rng = np.random.default_rng(99)
    x = _mk(rng, b, h, x_, y_)
    wr = _mk(rng, o, h, kx, ky, scale=1.0 / h)
    wi = _mk(rng, o, h, kx, ky, scale=1.0 / h)
    y = ops.spectral_layer_2d(x, wr, wi, (kx, ky), path="pallas",
                              variant="full")
    yref = ref_k.ref_fno2d(x, wr, wi, (kx, ky))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), **TOL32)


def test_three_paths_agree():
    """ref == xla == pallas (the core fusion-correctness invariant)."""
    rng = np.random.default_rng(1234)
    x = _mk(rng, 2, 16, 8, 64)
    wr = _mk(rng, 16, 16, scale=1 / 16.0)
    wi = _mk(rng, 16, 16, scale=1 / 16.0)
    outs = [ops.spectral_layer_2d(x, wr, wi, (3, 17), path=p,
                                  variant=v)
            for p, v in (("ref", "full"), ("xla", "full"),
                         ("pallas", "full"), ("pallas", "partial"))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=3e-4, atol=3e-4)
