"""The paper's contribution: fused FFT->CGEMM->iFFT kernels vs the staged
jnp.fft oracle — 1D/2D/3D (one rank-generic engine), shared and per-mode
weights, partial (paper-faithful) and full (beyond-paper) fusion,
shape/dtype sweeps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as ref_k

TOL32 = dict(rtol=2e-4, atol=2e-4)


def _mk(rng, *s, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(scale * rng.normal(size=s), dtype)


CASES_1D = [
    # B, H, O, N, K
    (4, 24, 16, 64, 17),
    (2, 64, 64, 256, 64),  # paper's FFT size / 50% truncation (Table 1)
    (1, 8, 8, 128, 32),  # paper's 25% truncation
    (3, 16, 32, 128, 65),
]


@pytest.mark.parametrize("b,h,o,n,k", CASES_1D)
@pytest.mark.parametrize("weight_mode", ["shared", "per_mode"])
def test_fused_fno1d(b, h, o, n, k, weight_mode):
    rng = np.random.default_rng(b * 7 + k)
    x = _mk(rng, b, h, n)
    wshape = (o, h) if weight_mode == "shared" else (o, h, k)
    wr = _mk(rng, *wshape, scale=1.0 / h)
    wi = _mk(rng, *wshape, scale=1.0 / h)
    y = ops.spectral_layer_1d(x, wr, wi, k, path="pallas")
    yref = ref_k.ref_fno1d(x, wr, wi, k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), **TOL32)


@pytest.mark.parametrize("dtype", [jnp.bfloat16])
def test_fused_fno1d_bf16(dtype):
    rng = np.random.default_rng(5)
    x = _mk(rng, 2, 16, 64, dtype=dtype)
    wr = _mk(rng, 8, 16, dtype=dtype, scale=1 / 16)
    wi = _mk(rng, 8, 16, dtype=dtype, scale=1 / 16)
    y = ops.spectral_layer_1d(x, wr, wi, 16, path="pallas")
    yref = ref_k.ref_fno1d(x.astype(jnp.float32), wr.astype(jnp.float32),
                           wi.astype(jnp.float32), 16)
    np.testing.assert_allclose(np.asarray(y, np.float32), np.asarray(yref),
                               rtol=0.05, atol=0.05)


CASES_2D = [
    # B, H, O, X, Y, KX, KY
    (2, 12, 8, 32, 32, 9, 9),
    (1, 16, 16, 64, 64, 16, 16),  # 50% per-axis truncation (paper 2D)
    (2, 8, 8, 32, 64, 8, 17),
]


@pytest.mark.parametrize("b,h,o,x_,y_,kx,ky", CASES_2D)
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_fused_fno2d_shared(b, h, o, x_, y_, kx, ky, variant):
    rng = np.random.default_rng(x_ + ky)
    x = _mk(rng, b, h, x_, y_)
    wr = _mk(rng, o, h, scale=1.0 / h)
    wi = _mk(rng, o, h, scale=1.0 / h)
    y = ops.spectral_layer_2d(x, wr, wi, (kx, ky), path="pallas",
                              variant=variant)
    yref = ref_k.ref_fno2d(x, wr, wi, (kx, ky))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), **TOL32)


@pytest.mark.parametrize("b,h,o,x_,y_,kx,ky", CASES_2D[:2])
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_fused_fno2d_permode(b, h, o, x_, y_, kx, ky, variant):
    """Per-mode weights through BOTH fusion variants — "partial" is the
    paper-faithful scheme, newly folded into the engine's weight-layout
    axis."""
    rng = np.random.default_rng(99)
    x = _mk(rng, b, h, x_, y_)
    wr = _mk(rng, o, h, kx, ky, scale=1.0 / h)
    wi = _mk(rng, o, h, kx, ky, scale=1.0 / h)
    y = ops.spectral_layer_2d(x, wr, wi, (kx, ky), path="pallas",
                              variant=variant)
    yref = ref_k.ref_fno2d(x, wr, wi, (kx, ky))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), **TOL32)


def test_fused_fno2d_permode_partial_matches_xla():
    """Engine per-mode partial vs the XLA reference (satellite parity)."""
    rng = np.random.default_rng(31)
    x = _mk(rng, 2, 8, 16, 32)
    wr = _mk(rng, 8, 8, 5, 9, scale=1.0 / 8)
    wi = _mk(rng, 8, 8, 5, 9, scale=1.0 / 8)
    y = ops.spectral_layer_2d(x, wr, wi, (5, 9), path="pallas",
                              variant="partial")
    yx = ops.spectral_layer_2d(x, wr, wi, (5, 9), path="xla")
    np.testing.assert_allclose(np.asarray(y), np.asarray(yx), **TOL32)


CASES_3D = [
    # B, H, O, X, Y, Z, KX, KY, KZ
    (1, 4, 4, 8, 8, 16, 3, 3, 5),
    (2, 8, 8, 16, 16, 16, 4, 4, 4),  # reduced fno3d shape (25% truncation)
]


@pytest.mark.parametrize("b,h,o,x_,y_,z_,kx,ky,kz", CASES_3D)
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_fused_fno3d_shared(b, h, o, x_, y_, z_, kx, ky, kz, variant):
    rng = np.random.default_rng(x_ + kz)
    x = _mk(rng, b, h, x_, y_, z_)
    wr = _mk(rng, o, h, scale=1.0 / h)
    wi = _mk(rng, o, h, scale=1.0 / h)
    y = ops.spectral_layer_3d(x, wr, wi, (kx, ky, kz), path="pallas",
                              variant=variant)
    yref = ref_k.ref_fnond(x, wr, wi, (kx, ky, kz))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), **TOL32)


@pytest.mark.parametrize("b,h,o,x_,y_,z_,kx,ky,kz", CASES_3D[:1])
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_fused_fno3d_permode(b, h, o, x_, y_, z_, kx, ky, kz, variant):
    rng = np.random.default_rng(7)
    x = _mk(rng, b, h, x_, y_, z_)
    wr = _mk(rng, o, h, kx, ky, kz, scale=1.0 / h)
    wi = _mk(rng, o, h, kx, ky, kz, scale=1.0 / h)
    y = ops.spectral_layer_3d(x, wr, wi, (kx, ky, kz), path="pallas",
                              variant=variant)
    yref = ref_k.ref_fnond(x, wr, wi, (kx, ky, kz))
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), **TOL32)


def test_compat_wrappers_match_oracle():
    """The fused_fno{1d,2d} compat wrappers must keep their positional
    operand contract wired to the engine correctly (they have no other
    callers in-repo)."""
    from repro.core import spectral as sp
    from repro.kernels import fused_fno1d as f1d, fused_fno2d as f2d
    rng = np.random.default_rng(11)
    # 1D: B,H,O already block multiples; rank-1 mats are 128-padded.
    x = _mk(rng, 2, 8, 64)
    wr, wi = _mk(rng, 8, 8, scale=1 / 8), _mk(rng, 8, 8, scale=1 / 8)
    mats = sp.fused_operand_mats((64,), (17,), "float32", False, 128)
    y = f1d.fused_fno1d_call(x, wr, wi, *mats, 2, 8, 8, True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref_k.ref_fno1d(x, wr, wi, 17)),
                               **TOL32)
    # 2D full: rank ≥ 2 needs no mode padding.
    x2 = _mk(rng, 2, 8, 16, 32)
    mats = sp.fused_operand_mats((16, 32), (5, 9), "float32", False, 0)
    y2 = f2d.fused_fno2d_full_call(x2, wr, wi, *mats, 2, 8, 8, True)
    np.testing.assert_allclose(
        np.asarray(y2), np.asarray(ref_k.ref_fno2d(x2, wr, wi, (5, 9))),
        **TOL32)


def test_operand_mats_cached():
    """The rank-generic operand factories are lru_cached: repeated layer
    traces must reuse the same host constants instead of rebuilding the
    O(N·K) matrices (satellite: mats caching)."""
    from repro.core import spectral as sp
    a = sp.fused_operand_mats((16, 16), (5, 5), "float32", False, 0)
    b = sp.fused_operand_mats((16, 16), (5, 5), "float32", False, 0)
    assert all(x is y for x, y in zip(a, b))
    c = sp.wgrad_operand_mats((16, 16), (5, 5), "float32", 0)
    d = sp.wgrad_operand_mats((16, 16), (5, 5), "float32", 0)
    assert all(x is y for x, y in zip(c, d))
    assert len(a) == 8 and len(c) == 8  # 4 stages × (re, im) at rank 2


def test_three_paths_agree():
    """ref == xla == pallas (the core fusion-correctness invariant)."""
    rng = np.random.default_rng(1234)
    x = _mk(rng, 2, 16, 8, 64)
    wr = _mk(rng, 16, 16, scale=1 / 16.0)
    wi = _mk(rng, 16, 16, scale=1 / 16.0)
    outs = [ops.spectral_layer_2d(x, wr, wi, (3, 17), path=p,
                                  variant=v)
            for p, v in (("ref", "full"), ("xla", "full"),
                         ("pallas", "full"), ("pallas", "partial"))]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=3e-4, atol=3e-4)
