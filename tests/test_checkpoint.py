"""Checkpointer: roundtrip, async, GC, corruption detection, trainer
restart semantics."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6).reshape(2, 3),
                       "c": [jnp.ones(3), jnp.zeros((2, 2))]}}


def test_roundtrip():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(5, t)
        assert ck.latest_step() == 5
        out = ck.restore(5, jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), t))
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                       np.asarray(b)),
            t, out)


def test_async_and_gc():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ck.save(s, _tree(s), blocking=False)
        ck.wait()
        assert ck.steps() == [3, 4]


def test_corruption_detected():
    t = _tree()
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, t)
        # corrupt the npz payload
        path = os.path.join(d, "step_1", "arrays.npz")
        data = dict(np.load(path))
        data["a"] = data["a"] + 1.0
        np.savez(path, **data)
        with pytest.raises(IOError, match="corruption"):
            ck.restore(1, t)


def test_atomicity_tmp_never_visible():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(7, _tree())
        names = os.listdir(d)
        assert names == ["step_7"], names
