"""Mixed-precision policy: bf16 spectral stack vs the f32 XLA reference.

Documented tolerances (ROADMAP.md §Precision policy): bf16 has an 8-bit
mantissa, so with f32 VMEM accumulators the fused layers hold ~1% relative
error forward and backward; casts happen only at ref-write boundaries —
outputs at the compute dtype, dx at the primal input dtype, dW at the
param dtype (f32 master weights under the bf16 preset).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.base import PrecisionPolicy
from repro.configs.fno import with_precision
from repro.kernels import ops

BF16 = PrecisionPolicy.from_name("bf16")
# bf16 I/O with f32 accumulation: observed max rel error ~0.5% across the
# rank sweep; 2% headroom. Gradients see the forward's bf16 error twice
# (once through the nonlinear readout's cotangent, once through the
# adjoint pipeline), so they get 5%.
TOL_BF16 = dict(rtol=2e-2, atol=2e-2)
TOL_BF16_GRAD = dict(rtol=5e-2, atol=5e-2)

_LAYERS = {1: ops.spectral_layer_1d, 2: ops.spectral_layer_2d,
           3: ops.spectral_layer_3d}
_CASES = {
    1: ((48,), (11,)),
    2: ((16, 32), (5, 9)),
    3: ((8, 8, 16), (3, 3, 5)),
}


def _mk(rng, *s, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(scale * rng.normal(size=s), dtype)


def _layer_fn(rank, modes, path, policy=None, variant="full"):
    fn = _LAYERS[rank]
    m = modes[0] if rank == 1 else modes
    kw = {} if rank == 1 else {"variant": variant}
    if policy is not None:
        kw["policy"] = policy
    return lambda x, wr, wi: fn(x, wr, wi, m, path=path, **kw)


def _allclose_rel(a, b, **tol):
    """assert_allclose with the tolerance scaled to the reference
    magnitude (bf16 error is relative to the output scale)."""
    scale = max(float(jnp.abs(b).max()), 1.0)
    np.testing.assert_allclose(np.asarray(a, np.float32) / scale,
                               np.asarray(b, np.float32) / scale, **tol)


@pytest.mark.parametrize("rank", [1, 2, 3])
@pytest.mark.parametrize("weight_mode", ["shared", "per_mode"])
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_bf16_forward_matches_f32_xla(rank, weight_mode, variant):
    """pallas bf16 policy forward vs the f32 XLA reference, every rank,
    both weight layouts, both fusion variants."""
    if rank == 1 and variant == "partial":
        pytest.skip("rank 1 has no partial variant")
    spatial, modes = _CASES[rank]
    rng = np.random.default_rng(rank * 11 + len(spatial))
    x = _mk(rng, 2, 8, *spatial)
    wshape = (6, 8) if weight_mode == "shared" else (6, 8) + modes
    wr = _mk(rng, *wshape, scale=1.0 / 8)
    wi = _mk(rng, *wshape, scale=1.0 / 8)
    y = _layer_fn(rank, modes, "pallas", BF16, variant)(x, wr, wi)
    assert y.dtype == jnp.bfloat16  # emitted at the compute dtype
    yref = _layer_fn(rank, modes, "xla")(x, wr, wi)
    _allclose_rel(y, yref, **TOL_BF16)


@pytest.mark.parametrize("rank", [1, 2, 3])
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_bf16_grads_match_f32_xla(rank, variant):
    """jax.grad through the bf16 fused pipeline (adjoint + wgrad kernels)
    vs f32 XLA: dx and dW agree to bf16 tolerance, and the cotangents are
    emitted at the PRIMAL dtypes — dx at x.dtype, dW at the f32 param
    dtype ("accumulate cotangents in f32 VMEM, emit dW at param dtype")."""
    if rank == 1 and variant == "partial":
        pytest.skip("rank 1 has no partial variant")
    spatial, modes = _CASES[rank]
    rng = np.random.default_rng(rank * 7)
    x = _mk(rng, 2, 8, *spatial)
    wr = _mk(rng, 6, 8, scale=1.0 / 8)
    wi = _mk(rng, 6, 8, scale=1.0 / 8)

    def grads(fn):
        loss = lambda x, wr, wi: jnp.sum(
            jnp.sin(fn(x, wr, wi).astype(jnp.float32)))
        return jax.grad(loss, argnums=(0, 1, 2))(x, wr, wi)

    gp = grads(_layer_fn(rank, modes, "pallas", BF16, variant))
    gx = grads(_layer_fn(rank, modes, "xla"))
    for name, a, b in zip(("dx", "dwr", "dwi"), gp, gx):
        assert a.dtype == jnp.float32, name  # primal (param/master) dtype
        _allclose_rel(a, b, err_msg=name, **TOL_BF16_GRAD)


def test_bf16_permode_wgrad_dtype_and_parity():
    """Per-mode weights: dW keeps the [O,H,k1,k2] layout and the f32 param
    dtype under the bf16 policy."""
    rng = np.random.default_rng(3)
    x = _mk(rng, 2, 8, 16, 32)
    wr = _mk(rng, 6, 8, 5, 9, scale=1.0 / 8)
    wi = _mk(rng, 6, 8, 5, 9, scale=1.0 / 8)

    def grads(fn):
        loss = lambda x, wr, wi: jnp.sum(
            jnp.sin(fn(x, wr, wi).astype(jnp.float32)))
        return jax.grad(loss, argnums=(1, 2))(x, wr, wi)

    gp = grads(_layer_fn(2, (5, 9), "pallas", BF16))
    gx = grads(_layer_fn(2, (5, 9), "xla"))
    for a, b in zip(gp, gx):
        assert a.dtype == jnp.float32 and a.shape == (6, 8, 5, 9)
        _allclose_rel(a, b, **TOL_BF16_GRAD)


@pytest.mark.parametrize("rank", [1, 2, 3])
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_bf16_fused_block_forward_and_grads(rank, variant):
    """The fused FNO block under the bf16 policy: forward within 2e-2 of
    the f32 XLA oracle, all four cotangents within 5e-2, and the emission
    dtypes honor the cast contract — y at the compute dtype, dx at the
    primal x dtype, dW/dW_b/dbias at the (f32 master) param dtype."""
    if rank == 1 and variant == "partial":
        pytest.skip("rank 1 has no partial variant")
    spatial, modes = _CASES[rank]
    rng = np.random.default_rng(rank * 13)
    x = _mk(rng, 2, 8, *spatial)
    wr = _mk(rng, 6, 8, scale=1.0 / 8)
    wi = _mk(rng, 6, 8, scale=1.0 / 8)
    wb = _mk(rng, 6, 8, scale=1.0 / 8)
    bias = _mk(rng, 6, scale=0.3)

    def block(path, policy=None):
        kw = {"policy": policy} if policy is not None else {}
        return lambda *a: ops.fno_block_nd(
            *a, modes, path=path,
            variant=variant if path == "pallas" else "full", **kw)

    y = block("pallas", BF16)(x, wr, wi, wb, bias)
    assert y.dtype == jnp.bfloat16
    _allclose_rel(y, block("xla")(x, wr, wi, wb, bias), **TOL_BF16)

    def grads(fn):
        loss = lambda *a: jnp.sum(jnp.sin(fn(*a).astype(jnp.float32)))
        return jax.grad(loss, argnums=(0, 1, 2, 3, 4))(x, wr, wi, wb, bias)

    gp = grads(block("pallas", BF16))
    gx = grads(block("xla"))
    for name, a, b in zip(("dx", "dwr", "dwi", "dwb", "dbias"), gp, gx):
        assert a.dtype == jnp.float32, name  # primal / master-param dtype
        _allclose_rel(a, b, err_msg=name, **TOL_BF16_GRAD)


def test_policy_presets():
    f32 = PrecisionPolicy.from_name("f32")
    assert f32 == PrecisionPolicy.from_name("float32") == PrecisionPolicy()
    assert not f32.is_mixed
    bf16 = PrecisionPolicy.from_name("bf16")
    assert bf16 == PrecisionPolicy.from_name("bfloat16")
    assert bf16.is_mixed
    assert bf16.compute_dtype == bf16.spectral_dtype == "bfloat16"
    assert bf16.param_dtype == bf16.accum_dtype == "float32"
    assert bf16.grad_acc_dtype == "float32"
    # non-preset dtype names keep the historical FNOConfig.dtype contract:
    # a uniform policy at that dtype (f32 accumulation)
    f64 = PrecisionPolicy.from_name("float64")
    assert f64.param_dtype == f64.compute_dtype == "float64"
    assert f64.accum_dtype == "float32"
    cfg = with_precision(get_config("fno2d", reduced=True), "bf16")
    assert cfg.precision == bf16 and cfg.dtype == "bfloat16"
    assert get_config("fno2d", reduced=True).precision == f32


def test_operand_mats_cache_keys_on_dtype():
    """Bugfix satellite: the lru_cached bundle builders key on the operand
    dtype — a bf16 trace must never be served a cached f32 bundle."""
    from repro.core import spectral as sp
    a32 = sp.fused_operand_mats((16, 16), (5, 5), "float32", False, 0)
    a16 = sp.fused_operand_mats((16, 16), (5, 5), "bfloat16", False, 0)
    assert all(m.dtype == jnp.float32 for m in a32)
    assert all(m.dtype == jnp.bfloat16 for m in a16)
    assert not any(x is y for x, y in zip(a32, a16))
    w32 = sp.wgrad_operand_mats((16, 16), (5, 5), "float32", 0)
    w16 = sp.wgrad_operand_mats((16, 16), (5, 5), "bfloat16", 0)
    assert all(m.dtype == jnp.bfloat16 for m in w16)
    assert not any(x is y for x, y in zip(w32, w16))
    # the batched outer-stage builders follow the same contract
    o32 = sp.outer_fwd_mats((8, 16), (3, 5), "float32")
    assert all(m.dtype == np.float32 for m in o32)
    i32 = sp.outer_inv_mats((8, 16), (3, 5), "float32")
    assert i32[0].shape == (15, 128) and o32[0].shape == (128, 15)


def test_outer_batched_matches_staged_chain():
    """Rank-3 partial satellite: the Kronecker-combined outer operands
    reproduce the per-axis transform chain they replaced."""
    from repro.core import spectral as sp
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, 3, 4, 8, 16)), jnp.float32)
    # staged: rDFT along s_3 (keep 5), then cDFT along s_2 (keep 3)
    zr, zi = sp.truncated_rdft(x, 5)
    zr, zi = (jnp.moveaxis(z, -2, -1) for z in (zr, zi))
    zr, zi = sp.truncated_cdft(zr, zi, 3)  # [2,3,4,K3=5,K2=3]
    mr, mi = sp.outer_fwd_mats((8, 16), (3, 5))
    xf = x.reshape(2, 3, 4, -1)
    br = xf @ jnp.asarray(mr)
    bi = xf @ jnp.asarray(mi)
    np.testing.assert_allclose(np.asarray(br).reshape(2, 3, 4, 5, 3),
                               np.asarray(zr), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(bi).reshape(2, 3, 4, 5, 3),
                               np.asarray(zi), rtol=1e-4, atol=1e-4)
    # inverse: staged icDFT along K_2 then irDFT along K_3
    tr, ti = sp.padded_icdft(zr, zi, 8)
    tr, ti = (jnp.moveaxis(t, -1, 3) for t in (tr, ti))
    y = sp.padded_irdft(tr, ti, 16)  # [2,3,4,8,16]
    er, ei = sp.outer_inv_mats((8, 16), (3, 5))
    zf_r = zr.reshape(2, 3, 4, -1)
    zf_i = zi.reshape(2, 3, 4, -1)
    yb = zf_r @ jnp.asarray(er) - zf_i @ jnp.asarray(ei)
    np.testing.assert_allclose(np.asarray(yb).reshape(2, 3, 4, 8, 16),
                               np.asarray(y), rtol=1e-4, atol=1e-4)


def test_train_step_bf16_smoke():
    """bf16 convergence smoke: the fused-path mixed-precision train step
    overfits one batch (loss drops), keeps master params in f32, and
    tracks the f32 run."""
    from repro.core import fno as fno_mod
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.train_step import make_train_step

    rng = np.random.default_rng(0)
    losses = {}
    for dname in ("f32", "bf16"):
        cfg = with_precision(get_config("fno2d", reduced=True), dname)
        params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
        assert all(l.dtype == jnp.float32
                   for l in jax.tree_util.tree_leaves(params))
        opt = AdamW(lr=constant(3e-3))
        step = jax.jit(make_train_step(cfg, opt, fno_path="pallas"))
        state = opt.init(params)
        batch = {"x": _mk(rng, 2, cfg.in_channels, *cfg.spatial),
                 "y": _mk(rng, 2, cfg.out_channels, *cfg.spatial)}
        hist, gnorms = [], []
        for _ in range(5):
            params, state, m = step(params, state, batch)
            hist.append(float(m["loss"]))
            gnorms.append(float(m["grad_norm"]))
        # master params stay f32 through the AdamW update
        assert all(l.dtype == jnp.float32
                   for l in jax.tree_util.tree_leaves(params))
        assert np.isfinite(hist).all()
        assert hist[-1] < hist[0], hist
        losses[dname] = (hist, gnorms)
        rng = np.random.default_rng(0)  # same batch for both runs
    np.testing.assert_allclose(losses["bf16"][0][0], losses["f32"][0][0],
                               rtol=3e-2)
    # grad-norm parity guards the bias-grad reduction: a bf16 sum over a
    # coherent cotangent field swamps (sticks at its first power of two)
    # unless the cast-VJP upcasts it to f32 first (core/fno._dense).
    np.testing.assert_allclose(losses["bf16"][1][0], losses["f32"][1][0],
                               rtol=5e-2)


def test_fno_model_bytes_predicts_bf16_reduction():
    """The dtype-aware roofline byte model: bf16 halves the compute-dtype
    traffic while master-param terms stay f32, so the predicted ratio
    lands strictly between 0.5 and 1."""
    from repro.roofline.analysis import dtype_bytes, fno_model_bytes

    assert dtype_bytes("float32") == dtype_bytes("f32") == 4
    assert dtype_bytes("bfloat16") == dtype_bytes("bf16") == 2
    cfg = get_config("fno2d", reduced=False)
    for variant in ("full", "partial"):
        b32 = fno_model_bytes(cfg, 4, variant=variant)
        b16 = fno_model_bytes(with_precision(cfg, "bf16"), 4,
                              variant=variant)
        ratio = b16 / b32
        assert 0.5 < ratio < 0.9, (variant, ratio)
    # inference has no param-master traffic beyond the weight reads
    i32 = fno_model_bytes(cfg, 4, training=False)
    i16 = fno_model_bytes(with_precision(cfg, "bf16"), 4, training=False)
    assert abs(i16 / i32 - 0.5) < 1e-6
    # partial fusion moves strictly more bytes than full fusion
    assert fno_model_bytes(cfg, 4, variant="partial") > fno_model_bytes(
        cfg, 4, variant="full")
    # whole-block fusion (PR 4) strictly reduces modeled traffic again —
    # the spectral-y / bypass-y / sum / GELU round trips disappear
    for training in (True, False):
        assert fno_model_bytes(cfg, 4, fuse_block=True,
                               training=training) < fno_model_bytes(
            cfg, 4, fuse_block=False, training=training), training
    # and cfg.fuse_block is the default source of the flag
    from repro.configs.fno import with_fuse_block
    assert fno_model_bytes(with_fuse_block(cfg), 4) == fno_model_bytes(
        cfg, 4, fuse_block=True)


def test_grad_acc_dtype_follows_policy():
    """make_train_step picks the policy's grad-accumulation dtype for the
    microbatch buffer (the existing grad_acc_dtype hook, now policy-fed)."""
    from repro.core import fno as fno_mod
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.train_step import make_train_step

    cfg = dataclasses.replace(
        with_precision(get_config("fno2d", reduced=True), "bf16"),
        policy=dataclasses.replace(PrecisionPolicy.from_name("bf16"),
                                   grad_acc_dtype="bfloat16"))
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=constant(1e-3))
    step = jax.jit(make_train_step(cfg, opt, fno_path="xla",
                                   microbatches=2))
    rng = np.random.default_rng(1)
    batch = {"x": _mk(rng, 4, cfg.in_channels, *cfg.spatial),
             "y": _mk(rng, 4, cfg.out_channels, *cfg.spatial)}
    p, s, m = step(params, opt.init(params), batch)
    assert bool(jnp.isfinite(m["loss"]))
    assert all(l.dtype == jnp.float32
               for l in jax.tree_util.tree_leaves(p))
