import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(ROOT, "src")


def run_with_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run python code in a subprocess with N virtual CPU devices.

    Needed because jax locks the device count at first init; the main test
    process stays single-device (per the assignment: smoke tests see 1
    device).
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    if proc.returncode != 0:
        raise AssertionError(
            f"subprocess failed:\nSTDOUT:\n{proc.stdout}\n"
            f"STDERR:\n{proc.stderr}")
    return proc.stdout


@pytest.fixture
def subproc():
    return run_with_devices
