"""Blockwise attention vs dense reference: GQA, causal, SWA, decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as attn


def dense_ref(q, k, v, causal, window, q_offset=0):
    b, sq, hq, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qf = q.reshape(b, sq, hkv, g, d).astype(np.float32)
    s = np.einsum("bqhgd,bkhd->bhgqk", qf, np.asarray(k, np.float32))
    s = s / np.sqrt(d)
    pos_q = q_offset + np.arange(sq)
    pos_k = np.arange(sk)
    mask = np.ones((sq, sk), bool)
    if causal:
        mask &= pos_k[None] <= pos_q[:, None]
    if window > 0:
        mask &= pos_k[None] > pos_q[:, None] - window
    s = np.where(mask[None, None, None], s, -1e30)
    p = np.exp(s - s.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    o = np.einsum("bhgqk,bkhd->bhgqd", p, np.asarray(v, np.float32))
    return o.transpose(0, 3, 1, 2, 4).reshape(b, sq, hq, d)


CASES = [
    # hq, hkv, causal, window, sq, sk
    (8, 8, True, 0, 64, 64),
    (8, 2, True, 0, 64, 64),  # GQA
    (4, 4, False, 0, 128, 128),  # bidirectional
    (8, 2, True, 16, 128, 128),  # SWA (dynamic-slice path)
    (6, 2, True, 24, 256, 256),  # SWA non-pow2 window
]


@pytest.mark.parametrize("hq,hkv,causal,window,sq,sk", CASES)
def test_blockwise_vs_dense(hq, hkv, causal, window, sq, sk):
    rng = np.random.default_rng(hq * sq + window)
    d = 16
    q = jnp.asarray(rng.normal(size=(2, sq, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(2, sk, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, sk, hkv, d)), jnp.float32)
    out = attn.multihead_attention(q, k, v, causal=causal, window=window,
                                   q_block=32, kv_block=32)
    ref = dense_ref(q, k, v, causal, window)
    np.testing.assert_allclose(np.asarray(out), ref, rtol=2e-4, atol=2e-4)


def test_decode_matches_last_row():
    """decode_attention_pos == last row of full causal attention."""
    rng = np.random.default_rng(3)
    b, s, hq, hkv, d = 2, 33, 8, 2, 16
    q = jnp.asarray(rng.normal(size=(b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, hkv, d)), jnp.float32)
    full = dense_ref(q, k, v, True, 0)
    # cache with padding slots beyond s
    smax = 48
    pad = smax - s
    kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
    vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    pos_k = jnp.where(jnp.arange(smax) < s, jnp.arange(smax), -1)
    out = attn.decode_attention_pos(q[:, -1:], kc, vc, pos_k, s - 1)
    np.testing.assert_allclose(np.asarray(out[:, 0]), full[:, -1],
                               rtol=2e-4, atol=2e-4)


def test_decode_ring_window():
    """Ring-buffer decode == full-cache windowed decode."""
    rng = np.random.default_rng(4)
    b, hkv, hq, d, w = 1, 2, 4, 8, 16
    total = 40  # tokens seen so far; new token position = total
    k_all = jnp.asarray(rng.normal(size=(b, total + 1, hkv, d)), jnp.float32)
    v_all = jnp.asarray(rng.normal(size=(b, total + 1, hkv, d)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, 1, hq, d)), jnp.float32)
    # full-cache reference
    pos_full = jnp.arange(total + 1)
    ref = attn.decode_attention_pos(q, k_all, v_all, pos_full, total,
                                    window=w)
    # ring cache of size sc >= w+1, holding the last sc tokens
    sc = 24
    idx = jnp.arange(total + 1 - sc, total + 1)
    slots = idx % sc
    kr = jnp.zeros((b, sc, hkv, d)).at[:, slots].set(k_all[:, idx])
    vr = jnp.zeros((b, sc, hkv, d)).at[:, slots].set(v_all[:, idx])
    pos_ring = jnp.zeros(sc, jnp.int32).at[slots].set(idx)
    out = attn.decode_attention_pos(q, kr, vr, pos_ring, total, window=w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-4,
                               atol=2e-4)
