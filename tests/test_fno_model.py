"""FNO model-level tests: path agreement, training convergence, loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import fno as fno_mod
from repro.data import pde
from repro.optim import AdamW
from repro.optim.schedule import constant
from repro.train.train_step import make_train_step


@pytest.mark.parametrize("arch", ["fno1d", "fno2d", "fno3d"])
def test_paths_agree_model_level(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    p = fno_mod.init_fno(key, cfg)
    x = jax.random.normal(key, (2, cfg.in_channels, *cfg.spatial))
    outs = [fno_mod.apply_fno(p, cfg, x, path=pth)
            for pth in ("ref", "xla", "pallas")]
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=2e-4, atol=2e-5)


def test_fno_learns_burgers():
    cfg = get_config("fno1d", reduced=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    opt = AdamW(lr=constant(1e-2), weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt, fno_path="xla"))
    state = opt.init(params)
    losses = []
    for i in range(50):
        batch = pde.burgers_batch(0, i, 8, cfg.spatial[0])
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.92 * losses[0], losses[::10]


def test_fno3d_learns_diffusion():
    """A few steps of the reduced 3D config on the spectral diffusion task
    must reduce the loss (the rank-3 stack end to end)."""
    cfg = get_config("fno3d", reduced=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    opt = AdamW(lr=constant(1e-2), weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt, fno_path="xla"))
    state = opt.init(params)
    losses = []
    for i in range(50):
        batch = pde.diffusion3d_batch(0, i, 4, cfg.spatial[0])
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.92 * losses[0], losses[::10]


def test_relative_l2():
    a = jnp.ones((2, 1, 8))
    assert float(fno_mod.relative_l2(a, a)) < 1e-6
    assert abs(float(fno_mod.relative_l2(2 * a, a)) - 1.0) < 1e-5


def test_grad_through_all_paths():
    cfg = get_config("fno1d", reduced=True)
    key = jax.random.PRNGKey(0)
    p = fno_mod.init_fno(key, cfg)
    x = jax.random.normal(key, (2, cfg.in_channels, *cfg.spatial))
    y = jnp.ones((2, cfg.out_channels, *cfg.spatial))
    for path in ("xla",):  # pallas interpret bwd covered at kernel level
        g = jax.grad(fno_mod.fno_loss)(p, cfg, {"x": x, "y": y}, path=path)
        norm = jax.tree_util.tree_reduce(
            lambda a, l: a + float(jnp.abs(l).sum()), g, 0.0)
        assert np.isfinite(norm) and norm > 0
