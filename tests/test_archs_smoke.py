"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED config of the same family and runs one forward
+ one optimizer step on CPU, asserting output shapes and finiteness; decode
consistency where the family supports it."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, runnable_cells
from repro.models import transformer as tf
from repro.models.frontend import fake_frontend_arrays
from repro.optim import AdamW
from repro.optim.schedule import constant
from repro.train.train_step import make_train_step


def _batch(cfg, key, b=2, s=32):
    extra = fake_frontend_arrays(cfg, b, s, key)
    batch = dict(extra)
    if "inputs_embeds" not in extra:
        batch["tokens"] = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    ls = s
    batch["labels"] = jax.random.randint(key, (b, ls), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_config(arch, reduced=True)
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg, jnp.float32)
    batch = _batch(cfg, key)
    logits, aux = tf.forward(params, cfg, batch.get("tokens"),
                             batch.get("inputs_embeds"),
                             batch.get("prefix_embeds"))
    s = 32 + (cfg.num_prefix_embeds if cfg.frontend == "vision" else 0)
    assert logits.shape == (2, s, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())

    opt = AdamW(lr=constant(1e-3))
    step = make_train_step(cfg, opt)
    p2, o2, m = step(params, opt.init(params), batch)
    assert np.isfinite(float(m["loss"]))
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, l: a + float(jnp.abs(l).sum()),
        jax.tree_util.tree_map(jnp.subtract, p2, params), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if get_config(a).is_decoder])
def test_prefill_decode_consistency(arch):
    cfg = get_config(arch, reduced=True)
    if cfg.num_experts:  # avoid capacity-drop nondeterminism across T
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    key = jax.random.PRNGKey(1)
    params = tf.init_lm(key, cfg, jnp.float32)
    b, s = 2, 48
    tokens = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits_full, _ = tf.forward(params, cfg, tokens)
    _, cache = tf.prefill(params, cfg, tokens[:, :s - 1], max_len=s + 4)
    logits_dec, cache2 = tf.decode_step(params, cfg, cache, tokens[:, s - 1])
    np.testing.assert_allclose(np.asarray(logits_dec),
                               np.asarray(logits_full[:, -1]),
                               rtol=2e-3, atol=2e-3)
    assert int(cache2["len"]) == s


def test_cell_grid_and_skips():
    # 10 LM archs + 4 FNO archs, 4 shapes each — EVERY seeded config is
    # enumerated (the registry audit contract,
    # analysis.ast_lint.check_config_registry).
    cells = list(runnable_cells())
    assert len(cells) == 56
    skips = [(a, s) for a, s, r in cells if r]
    assert ("hubert-xlarge", "decode_32k") in skips
    assert ("hubert-xlarge", "long_500k") in skips
    for a in ("qwen2-1.5b", "nemotron-4-340b", "chatglm3-6b",
              "internvl2-26b", "arctic-480b"):
        assert (a, "long_500k") in skips
    # sub-quadratic archs run long_500k
    for a, s, r in cells:
        if a in ("mamba2-370m", "hymba-1.5b", "mixtral-8x7b", "gemma3-27b") \
                and s == "long_500k":
            assert r is None
    # FNO archs (fno2d-large included): train + batched-serve cells run,
    # decode shapes carry a reason
    by = {(a, s): r for a, s, r in cells}
    for a in ("fno1d", "fno2d", "fno2d-large", "fno3d"):
        assert by[(a, "train_4k")] is None
        assert by[(a, "prefill_32k")] is None
        assert by[(a, "decode_32k")]
        assert by[(a, "long_500k")]


@pytest.mark.parametrize("arch,target_b", [
    ("qwen2-1.5b", 1.54e9), ("gemma3-27b", 27e9), ("nemotron-4-340b", 341e9),
    ("chatglm3-6b", 6.2e9), ("mamba2-370m", 0.368e9),
    ("hubert-xlarge", 0.96e9), ("internvl2-26b", 19.9e9),
    ("mixtral-8x7b", 46.7e9), ("arctic-480b", 477e9),
    ("hymba-1.5b", 1.64e9),
])
def test_param_counts(arch, target_b):
    n = get_config(arch).param_count()
    assert abs(n - target_b) / target_b < 0.05, (arch, n, target_b)
