"""Hypothesis property tests on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import spectral as sp
from repro.kernels import ops, ref as ref_k

hypothesis.settings.register_profile(
    "ci", deadline=None, max_examples=20,
    suppress_health_check=list(hypothesis.HealthCheck))
hypothesis.settings.load_profile("ci")

dims = st.sampled_from([16, 32, 64, 128])


@given(n=dims, frac=st.floats(0.1, 1.0), seed=st.integers(0, 2 ** 16))
def test_truncated_rdft_matches_fft(n, frac, seed):
    k = max(1, min(int(frac * (n // 2 + 1)), n // 2 + 1))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, n)), jnp.float32)
    xr, xi = sp.truncated_rdft(x, k)
    ref = np.fft.rfft(np.asarray(x), axis=-1)[..., :k]
    np.testing.assert_allclose(np.asarray(xr), ref.real, rtol=1e-3,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(xi), ref.imag, rtol=1e-3,
                               atol=1e-3)


@given(n=dims, frac=st.floats(0.1, 0.95), seed=st.integers(0, 2 ** 16))
def test_padded_irdft_matches_irfft(n, frac, seed):
    k = max(1, int(frac * (n // 2)))
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(2, k)) + 1j * rng.normal(size=(2, k))
    y = sp.padded_irdft(jnp.asarray(z.real, jnp.float32),
                        jnp.asarray(z.imag, jnp.float32), n)
    ref = np.fft.irfft(np.pad(z, ((0, 0), (0, n // 2 + 1 - k))), n=n,
                       axis=-1)
    np.testing.assert_allclose(np.asarray(y), ref, rtol=1e-3, atol=1e-4)


@given(seed=st.integers(0, 2 ** 16))
def test_spectral_layer_linearity(seed):
    """The whole fused layer is linear in x: f(a·x1 + x2) = a·f(x1)+f(x2)."""
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    x1, x2 = mk(2, 8, 32), mk(2, 8, 32)
    wr, wi = mk(8, 8) / 8, mk(8, 8) / 8
    f = lambda x: ops.spectral_layer_1d(x, wr, wi, 9, path="xla")
    lhs = f(1.7 * x1 + x2)
    rhs = 1.7 * f(x1) + f(x2)
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-3,
                               atol=1e-4)


@given(seed=st.integers(0, 2 ** 16), n=dims)
def test_truncation_contracts_energy(seed, n):
    """Truncation is an orthogonal projection: output energy of the
    identity-weight layer never exceeds input energy (Parseval)."""
    k = n // 4 + 1
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, 4, n)), jnp.float32)
    eye = jnp.eye(4, dtype=jnp.float32)
    y = ops.spectral_layer_1d(x, eye, jnp.zeros_like(eye), k, path="xla")
    e_in = float(jnp.sum(x ** 2))
    e_out = float(jnp.sum(y ** 2))
    assert e_out <= e_in * (1 + 1e-4)


@given(seed=st.integers(0, 2 ** 16))
def test_fusion_equals_staged(seed):
    """pallas fused == ref staged (the paper's central correctness claim)."""
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    x = mk(2, 8, 64)
    wr, wi = mk(8, 8) / 8, mk(8, 8) / 8
    y1 = ops.spectral_layer_1d(x, wr, wi, 17, path="pallas")
    y0 = ref_k.ref_fno1d(x, wr, wi, 17)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), rtol=2e-4,
                               atol=2e-4)


@given(dtype=st.sampled_from(["f32", "bf16"]), seed=st.integers(0, 2 ** 16))
@settings(max_examples=12)
def test_fusion_equals_staged_both_dtypes(dtype, seed):
    """The fused layer tracks the staged oracle under BOTH precision
    presets — the PrecisionPolicy invariant: bf16 only loosens the
    tolerance (f32 accumulators), it never changes the math."""
    from repro.configs.base import PrecisionPolicy
    pol = PrecisionPolicy.from_name(dtype)
    tol = 2e-4 if dtype == "f32" else 2e-2
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    x = mk(2, 8, 64)
    wr, wi = mk(8, 8) / 8, mk(8, 8) / 8
    y1 = ops.spectral_layer_1d(x, wr, wi, 17, path="pallas", policy=pol)
    assert jnp.dtype(y1.dtype).name == pol.compute_dtype
    y0 = ref_k.ref_fno1d(x, wr, wi, 17)
    scale = max(float(jnp.abs(np.asarray(y0)).max()), 1.0)
    np.testing.assert_allclose(np.asarray(y1, np.float32) / scale,
                               np.asarray(y0) / scale, rtol=tol, atol=tol)


@given(n=dims, frac=st.floats(0.1, 0.9), seed=st.integers(0, 2 ** 16))
def test_rdft_roundtrip_is_projection(n, frac, seed):
    """Adjoint identity of the matrix factories: irDFT(rDFT(x)) equals the
    spectral truncation of x (an orthogonal projection) — idempotent and
    energy-contracting."""
    k = max(1, int(frac * (n // 2 + 1)))
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, n)), jnp.float32)
    once = sp.padded_irdft(*sp.truncated_rdft(x, k), n)
    ref = np.fft.irfft(np.pad(np.fft.rfft(np.asarray(x), axis=-1)[:, :k],
                              ((0, 0), (0, n // 2 + 1 - k))), n=n, axis=-1)
    np.testing.assert_allclose(np.asarray(once), ref, rtol=1e-3, atol=1e-4)
    twice = sp.padded_irdft(*sp.truncated_rdft(once, k), n)
    np.testing.assert_allclose(np.asarray(twice), np.asarray(once),
                               rtol=1e-3, atol=1e-4)
    assert float(jnp.sum(once ** 2)) <= float(jnp.sum(x ** 2)) * (1 + 1e-4)


@given(n=dims, seed=st.integers(0, 2 ** 16))
def test_real_input_spectrum_conjugate_symmetric(n, seed):
    """Conjugate symmetry of the real-input path: the full complex DFT of
    a real signal satisfies X[m] == conj(X[(N-m) mod N]) — the invariant
    that lets the engine carry only n//2+1 rFFT bins."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2, n)), jnp.float32)
    xr, xi = sp.truncated_cdft(x, jnp.zeros_like(x), n)
    idx = (-np.arange(n)) % n
    np.testing.assert_allclose(np.asarray(xr), np.asarray(xr)[:, idx],
                               rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(xi), -np.asarray(xi)[:, idx],
                               rtol=1e-3, atol=1e-3)


_RANK_CASES = {
    1: ((32,), (9,)),
    2: ((16, 16), (5, 5)),
    3: ((8, 8, 8), (3, 3, 3)),
}
_RANK_LAYERS = {1: lambda *a, **k: ops.spectral_layer_1d(*a, **k),
                2: lambda *a, **k: ops.spectral_layer_2d(*a, **k),
                3: lambda *a, **k: ops.spectral_layer_3d(*a, **k)}


@given(rank=st.sampled_from([1, 2, 3]),
       weight_mode=st.sampled_from(["shared", "per_mode"]),
       seed=st.integers(0, 2 ** 16))
@settings(max_examples=12)
def test_engine_matches_ref_all_ranks(rank, weight_mode, seed):
    """One rank-generic engine == the jnp.fft staged oracle for every
    spatial rank and weight layout (the dedup-refactor invariant)."""
    spatial, modes = _RANK_CASES[rank]
    rng = np.random.default_rng(seed)
    mk = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)
    h = o = 4
    x = mk(2, h, *spatial)
    wshape = (o, h) if weight_mode == "shared" else (o, h) + modes
    wr, wi = mk(*wshape) / h, mk(*wshape) / h
    m = modes[0] if rank == 1 else modes
    y = _RANK_LAYERS[rank](x, wr, wi, m, path="pallas")
    yref = ref_k.ref_fnond(x, wr, wi, modes)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yref), rtol=2e-4,
                               atol=2e-4)


@given(n=st.sampled_from([4, 8, 16, 32, 64, 128, 256, 512]),
       frac=st.floats(0.05, 1.0))
def test_prune_counts_monotone(n, frac):
    """Pruned-FFT op count is monotone in k, bounded by the full FFT, and
    reproduces the paper's Fig. 5 figures."""
    k = max(1, int(frac * n))
    ops_k = sp.pruned_fft_ops(n, k)
    assert 0 < ops_k <= sp.fft_ops(n)
    if k > 1:
        assert sp.pruned_fft_ops(n, k - 1) <= ops_k
    assert sp.pruned_fft_ops(4, 1) / sp.fft_ops(4) == 0.375
    assert sp.pruned_fft_ops(4, 2) / sp.fft_ops(4) == 0.75


@given(seed=st.integers(0, 2 ** 16),
       e=st.sampled_from([2, 4, 8]), k=st.sampled_from([1, 2]))
def test_moe_gates_normalized_and_conserving(seed, e, k):
    from repro.configs import get_config
    import dataclasses
    from repro.models import moe as moe_mod
    cfg = dataclasses.replace(get_config("mixtral-8x7b", reduced=True),
                              num_experts=e, top_k=k, capacity_factor=8.0)
    key = jax.random.PRNGKey(seed)
    p = moe_mod.moe_init(key, cfg, jnp.float32)
    x = jax.random.normal(key, (2, 16, cfg.d_model))
    y, aux = moe_mod.apply_moe(p, x, cfg)
    assert y.shape == x.shape
    assert bool(jnp.isfinite(y).all())
    # E·Σ load·importance ≈ 1 at balance; can dip slightly below when the
    # top-k load distribution diverges from softmax importance
    assert 0.5 <= float(aux) < float(e)
