"""Device-resident multi-step rollout (ISSUE 10, docs/DESIGN.md §10).

``make_fno_rollout_step`` runs a K-step autoregressive trajectory inside
one jitted ``lax.scan`` without the carry ever leaving HBM. These tests
pin its math: the scan must equal a STAGED per-step loop (one apply_fno
call per step, output fed back by hand) for every rank and both
precision presets, the pallas rollout must match the XLA oracle, and the
fno2d channel-feedback rule (prediction replaces the solution channel,
coordinate channels persist) must hold. The companion trace contract —
exactly ``num_layers`` pallas_calls regardless of K — lives in
tests/test_lint.py and ``analysis.jaxpr_lint.lint_rollout``.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.configs.fno import with_precision
from repro.core import fno as fno_mod
from repro.train.serve_fno_step import make_fno_rollout_step

PARITY_TOL = 2e-4  # same contract as the serving/resilience suites


def _cfg(arch, prec="f32"):
    cfg = dataclasses.replace(get_config(arch, reduced=True),
                              path="pallas", fuse_block=True)
    return with_precision(cfg, prec) if prec != "f32" else cfg


def _setup(cfg, batch=2, seed=0):
    key = jax.random.PRNGKey(seed)
    params = fno_mod.init_fno(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (batch, cfg.in_channels) + tuple(cfg.spatial))
    return params, x


def staged_rollout(params, cfg, x, steps, path):
    """The oracle: K separate apply_fno calls with the feedback done by
    hand between steps — what a serving loop WITHOUT the device-resident
    scan would compute (each step round-tripping host/HBM)."""
    x = jnp.asarray(x, jnp.dtype(cfg.precision.compute_dtype))
    keep = cfg.in_channels - cfg.out_channels
    for _ in range(steps):
        y = fno_mod.apply_fno(params, cfg, x, path=path)
        x = jnp.concatenate([y, x[:, cfg.out_channels:]], 1) if keep else y
    return x[:, :cfg.out_channels]


@pytest.mark.parametrize("prec", ["f32", "bf16"])
@pytest.mark.parametrize("arch", ["fno1d", "fno2d", "fno3d"])
def test_rollout_matches_staged_loop(arch, prec):
    """K-step scan rollout == the staged per-step loop at the SAME path
    and precision, every rank x both presets. Same ops in the same order,
    so this holds to fp tolerance even under bf16."""
    cfg = _cfg(arch, prec)
    params, x = _setup(cfg)
    roll = jax.jit(make_fno_rollout_step(cfg),
                   static_argnames=("steps",))
    for steps in (1, 3):
        got = np.asarray(roll(params, {"x": x}, steps=steps),
                         np.float32)
        want = np.asarray(staged_rollout(params, cfg, x, steps, "pallas"),
                          np.float32)
        assert got.shape == (x.shape[0], cfg.out_channels) + tuple(
            cfg.spatial)
        np.testing.assert_allclose(got, want, rtol=0, atol=PARITY_TOL)
        assert np.isfinite(got).all()


@pytest.mark.parametrize("arch", ["fno1d", "fno2d", "fno3d"])
def test_rollout_pallas_matches_xla_oracle_f32(arch):
    """The fused pallas rollout vs a staged XLA rollout: per-step kernel
    parity (2e-4) must not compound past the contract over K=3 steps on
    the reduced problems."""
    cfg = _cfg(arch)
    params, x = _setup(cfg)
    roll = jax.jit(make_fno_rollout_step(cfg),
                   static_argnames=("steps",))
    got = np.asarray(roll(params, {"x": x}, steps=3))
    want = np.asarray(staged_rollout(params, cfg, x, 3, "xla"))
    np.testing.assert_allclose(got, want, rtol=0, atol=PARITY_TOL)


def test_rollout_channel_feedback_preserves_conditioning():
    """fno2d serves (a, x, y) -> u: across rollout steps the prediction
    replaces channel 0 while the coordinate-grid channels 1..2 persist
    verbatim. Pin that by showing the K=2 rollout equals a hand-built
    step whose input is [u_1, coords] exactly."""
    cfg = _cfg("fno2d")
    assert cfg.in_channels == 3 and cfg.out_channels == 1
    params, x = _setup(cfg)
    roll = jax.jit(make_fno_rollout_step(cfg),
                   static_argnames=("steps",))
    u1 = fno_mod.apply_fno(params, cfg, x, path="pallas")
    x2 = jnp.concatenate([u1, x[:, 1:].astype(u1.dtype)], axis=1)
    want = np.asarray(fno_mod.apply_fno(params, cfg, x2, path="pallas"))
    got = np.asarray(roll(params, {"x": x}, steps=2))
    np.testing.assert_allclose(got, want, rtol=0, atol=PARITY_TOL)
    # ...and feeding DIFFERENT conditioning must change the answer (the
    # coords really flow through, they are not dropped by the carry).
    x_shift = x.at[:, 1:].add(0.5)
    other = np.asarray(roll(params, {"x": x_shift}, steps=2))
    assert not np.allclose(got, other, atol=1e-3)


def test_rollout_depth_changes_answer():
    """Each extra step applies the operator again — K=1, 2, 3 must give
    three distinct trajectories (the scan really iterates)."""
    cfg = _cfg("fno2d")
    params, x = _setup(cfg)
    roll = jax.jit(make_fno_rollout_step(cfg),
                   static_argnames=("steps",))
    outs = [np.asarray(roll(params, {"x": x}, steps=k)) for k in (1, 2, 3)]
    for a, b in zip(outs, outs[1:]):
        assert not np.allclose(a, b, atol=1e-5)


def test_rollout_output_dtype_is_compute_dtype():
    """The carry is cast ONCE up front (policy-owned cast), so the K-step
    output dtype matches the single-step serve output for both presets."""
    for prec, want in (("f32", jnp.float32), ("bf16", jnp.bfloat16)):
        cfg = _cfg("fno2d", prec)
        params, x = _setup(cfg)
        roll = make_fno_rollout_step(cfg)
        y = roll(params, {"x": x}, steps=2)
        assert y.dtype == jnp.dtype(want), (prec, y.dtype)


def test_rollout_rejects_widening_head():
    """out_channels > in_channels has no feedback rule (the prediction
    cannot seed the next input) — constructing the rollout must fail
    loudly, not produce a silently wrong concat."""
    cfg = dataclasses.replace(get_config("fno1d", reduced=True),
                              out_channels=2)
    assert cfg.out_channels > cfg.in_channels
    with pytest.raises(ValueError, match="out_channels <= in_channels"):
        make_fno_rollout_step(cfg)


def test_rollout_steps_is_static():
    """``steps`` is a trace-time constant (static_argnames under jit, a
    functools.partial bind under make_jaxpr) — two depths are two cache
    entries, both correct."""
    cfg = _cfg("fno1d")
    params, x = _setup(cfg)
    roll = jax.jit(make_fno_rollout_step(cfg),
                   static_argnames=("steps",))
    a = np.asarray(roll(params, {"x": x}, steps=1))
    b = np.asarray(roll(params, {"x": x}, steps=2))
    assert a.shape == b.shape and not np.array_equal(a, b)
    # and the partial-bind tracing idiom the lint/driver contract uses
    fn = functools.partial(make_fno_rollout_step(cfg), steps=2)
    jaxpr = jax.make_jaxpr(fn)(params, {"x": x})
    assert jaxpr.jaxpr is not None
