"""Per-kernel allclose: truncated rDFT / padded irDFT Pallas kernels vs the
jnp.fft oracle, swept over shapes and dtypes (interpret mode on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref as ref_k

SHAPES = [
    ((4, 64), 16),
    ((2, 3, 128), 33),
    ((1, 256), 64),
    ((5, 7, 32), 9),
    ((8, 128), 65),  # modes = N/2+1 (Nyquist included)
]
DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dt):
    return dict(rtol=1e-4, atol=1e-4) if dt == jnp.float32 else \
        dict(rtol=0.05, atol=0.05)


@pytest.mark.parametrize("shape,modes", SHAPES)
@pytest.mark.parametrize("dtype", DTYPES)
def test_truncated_rdft(shape, modes, dtype):
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=shape), dtype)
    xr, xi = ops.truncated_rdft(x, modes, path="pallas")
    rr, ri = ref_k.ref_truncated_rdft(x.astype(jnp.float32), modes)
    np.testing.assert_allclose(np.asarray(xr, np.float32), rr, **_tol(dtype))
    np.testing.assert_allclose(np.asarray(xi, np.float32), ri, **_tol(dtype))


@pytest.mark.parametrize("shape,modes", SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_padded_irdft(shape, modes, dtype):
    rng = np.random.default_rng(1)
    n = shape[-1]
    zshape = shape[:-1] + (modes,)
    zr = jnp.asarray(rng.normal(size=zshape), dtype)
    zi = jnp.asarray(rng.normal(size=zshape), dtype)
    y = ops.padded_irdft(zr, zi, n, path="pallas")
    yr = ref_k.ref_padded_irdft(zr, zi, n)
    np.testing.assert_allclose(np.asarray(y), yr, **_tol(dtype))


def test_roundtrip_exact_when_bandlimited():
    """trunc->pad roundtrip is exact iff the signal is band-limited."""
    rng = np.random.default_rng(2)
    n, k = 128, 20
    zr = jnp.asarray(rng.normal(size=(3, k)), jnp.float32)
    zi = jnp.asarray(rng.normal(size=(3, k)), jnp.float32)
    zi = zi.at[:, 0].set(0.0)  # DC imag is dropped by irfft
    x = ops.padded_irdft(zr, zi, n, path="xla")  # band-limited by constr.
    xr, xi = ops.truncated_rdft(x, k, path="pallas")
    np.testing.assert_allclose(np.asarray(xr), np.asarray(zr),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(xi), np.asarray(zi),
                               rtol=1e-4, atol=1e-4)
