"""Serving-primitive and continuous-batching property suite (ISSUE 10).

Hypothesis properties over the bucket ladder (``serve_fno_step``:
smallest-fit, padding masks, oversize chunk-and-tail reassembly) and the
coalescing queue (``serve_queue``: deadline contract, FIFO within a
bucket, conservation), plus deterministic unit tests of the tier over a
fake executor and one live pass over the real fused engine."""
import dataclasses

import numpy as np
import pytest

from repro.train import serve_queue as sq
from repro.train.serve_fno_step import (bucket_sizes, pad_to_bucket,
                                        pick_bucket)
from repro.train.serve_runtime import RequestRejected

# hypothesis is optional (requirements-dev.txt installs it in CI; the
# runtime image may lack it). Unlike test_property.py, only the @given
# properties skip without it — the deterministic queue tests in this
# module still run everywhere.
try:
    import hypothesis
    import hypothesis.strategies as st
    from hypothesis import given

    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=20,
        suppress_health_check=list(hypothesis.HealthCheck))
    hypothesis.settings.load_profile("ci")
except ImportError:  # pragma: no cover - exercised on hypothesis-less images
    hypothesis = None

    class st:  # minimal stand-ins so the decorators below still parse
        @staticmethod
        def _stub(*a, **k):
            return None
        integers = floats = sampled_from = tuples = lists = _stub

    def given(**kw):
        return pytest.mark.skip(reason="hypothesis not installed")


# ---------------------------------------------------------------------------
# bucket-ladder primitives
# ---------------------------------------------------------------------------
@given(quantum=st.integers(1, 8), max_batch=st.integers(1, 64))
def test_bucket_ladder_geometric_and_quantized(quantum, max_batch):
    buckets = bucket_sizes(max_batch, quantum=quantum)
    assert buckets[0] == quantum
    assert buckets[-1] >= max_batch
    for a, b in zip(buckets, buckets[1:]):
        assert b == 2 * a  # geometric: one jit entry per doubling
    assert all(b % quantum == 0 for b in buckets)
    # minimal: dropping the top bucket would no longer cover max_batch
    if len(buckets) > 1:
        assert buckets[-2] < max_batch


@given(quantum=st.integers(1, 8), max_batch=st.integers(1, 64),
       n=st.integers(1, 96))
def test_pick_bucket_is_smallest_fit(quantum, max_batch, n):
    buckets = bucket_sizes(max_batch, quantum=quantum)
    b = pick_bucket(n, buckets)
    assert b in buckets
    if n <= buckets[-1]:
        assert b >= n
        smaller = [x for x in buckets if x < b]
        assert all(x < n for x in smaller)  # nothing smaller would fit
    else:
        assert b == buckets[-1]  # oversize: caller chunks at the top


@given(n=st.integers(1, 16), extra=st.integers(0, 16),
       seed=st.integers(0, 2 ** 16))
def test_pad_to_bucket_masks_and_preserves(n, extra, seed):
    bucket = n + extra
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 3, 4)).astype(np.float32)
    xp, m = pad_to_bucket(x, bucket)
    assert m == n and xp.shape[0] == bucket
    assert np.array_equal(np.asarray(xp)[:n], x)  # payload bit-exact
    assert not np.asarray(xp)[n:].any()  # padding is zeros


@given(quantum=st.integers(1, 4), max_batch=st.integers(1, 16),
       n=st.integers(1, 80), seed=st.integers(0, 2 ** 16))
def test_oversize_chunk_and_tail_reassembles_bit_exactly(quantum, max_batch,
                                                         n, seed):
    # Mirror FNOServer.__call__'s oversize loop with an identity step:
    # chunk at the largest bucket, pad each chunk to its own bucket,
    # unpad, concatenate — the round trip must be bit-exact.
    buckets = bucket_sizes(max_batch, quantum=quantum)
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n, 2, 3)).astype(np.float32)
    top = buckets[-1]
    ys = []
    for s in range(0, n, top):
        chunk = x[s:s + top]
        b = pick_bucket(chunk.shape[0], buckets)
        xp, m = pad_to_bucket(chunk, b)
        assert xp.shape[0] == b and m == chunk.shape[0]
        ys.append(np.asarray(xp)[:m])
    out = np.concatenate(ys, 0)
    assert out.shape == x.shape
    assert np.array_equal(out, x)


# ---------------------------------------------------------------------------
# the coalescing queue (fake executor — no jax)
# ---------------------------------------------------------------------------
class FakeEngine:
    """Identity executor that records every dispatched batch."""

    def __init__(self, buckets=(2, 4, 8), fail=False):
        self.buckets = buckets
        self.calls = []
        self.fail = fail

    def __call__(self, x, rollout_steps=1):
        self.calls.append((int(x.shape[0]), int(rollout_steps)))
        if self.fail:
            raise RuntimeError("injected engine failure")
        return np.asarray(x)


def _payload(a, i):
    # Each request's samples carry its schedule index, so output routing
    # is checkable per request.
    return np.full((a.n, 1), float(i), np.float32)


schedules = st.lists(
    st.tuples(st.floats(1e-4, 0.02),  # inter-arrival gap
              st.integers(1, 5),  # samples
              st.sampled_from([1, 2]),  # rollout depth
              st.sampled_from([None, 0.01, 0.05])),  # deadline
    min_size=1, max_size=30)


def _mk_schedule(raw):
    t, out = 0.0, []
    for gap, n, steps, dl in raw:
        t += gap
        out.append(sq.Arrival(t, n, steps, dl))
    return out


def _replay(raw, queue_limit=4, coalesce_s=0.004):
    sched = _mk_schedule(raw)
    eng = FakeEngine()
    cbs = sq.ContinuousBatchingServer(
        eng, buckets=eng.buckets, queue_limit=queue_limit,
        coalesce_s=coalesce_s, clock=sq.VirtualClock(),
        service_model=lambda bucket, steps: 1e-3 * steps + 2e-4 * bucket)
    rep = cbs.replay(sched, _payload)
    return cbs, eng, rep, sched


@given(raw=schedules)
def test_queue_conservation(raw):
    cbs, _, rep, _ = _replay(raw)
    s = rep["stats"]
    assert s["offered"] == len(raw)
    assert s["offered"] == s["accepted"] + s["shed"]
    # replay drains fully: every accepted request reached a terminal state
    assert s["accepted"] == (s["completed"] + s["deadline_exceeded"]
                             + s["failed"])
    assert cbs.queue_depth() == 0
    # per-request statuses agree with the counters
    by_status = {}
    for r in cbs.requests.values():
        by_status[r.status] = by_status.get(r.status, 0) + 1
    assert by_status.get("done", 0) == s["completed"]
    assert by_status.get("deadline", 0) == s["deadline_exceeded"]
    assert by_status.get("failed", 0) == s["failed"]


@given(raw=schedules)
def test_no_request_served_past_deadline(raw):
    cbs, _, _, _ = _replay(raw)
    for r in cbs.requests.values():
        if r.status == "done":
            assert r.t_complete >= r.t_dispatch >= r.t_enqueue
            if r.deadline_t is not None:
                # served => on time; late == DeadlineExceeded, never both
                assert r.t_complete <= r.deadline_t + 1e-12
        if r.status == "deadline":
            assert r.y is None and "deadline" in r.error


@given(raw=schedules)
def test_fifo_within_bucket_and_payload_routing(raw):
    cbs, eng, _, _ = _replay(raw)
    # Every dispatched batch is uniform in rollout depth and within the
    # ladder's largest bucket unless a single oversize request rode alone.
    done = [r for r in cbs.requests.values() if r.status == "done"]
    for n, steps in eng.calls:
        assert steps in (1, 2)
    batches = {}
    for r in done:
        batches.setdefault(r.t_dispatch, []).append(r)
    for members in batches.values():
        sizes = [m.n for m in members]
        assert len({m.rollout_steps for m in members}) == 1
        assert sum(sizes) <= eng.buckets[-1] or len(members) == 1
        # FIFO within the bucket: coalesced members in admission order
        idxs = [m.idx for m in members]
        assert idxs == sorted(idxs)
    # payload routing: each request got back exactly its own samples
    for r in done:
        assert r.y.shape[0] == r.n
        assert (np.asarray(r.y) == np.asarray(r.y).flat[0]).all()
    # identity engine: request i's payload is the schedule index it was
    # admitted with — cross-request mixups would show here
    accepted = sorted(done, key=lambda r: r.idx)
    vals = [float(np.asarray(r.y).flat[0]) for r in accepted]
    assert vals == sorted(vals)


def test_submit_sheds_at_queue_limit_without_enqueue():
    eng = FakeEngine()
    cbs = sq.ContinuousBatchingServer(eng, buckets=eng.buckets,
                                      queue_limit=2,
                                      clock=sq.VirtualClock())
    x = np.zeros((1, 1), np.float32)
    assert cbs.submit(x) == 0 and cbs.submit(x) == 1
    with pytest.raises(RequestRejected):
        cbs.submit(x)
    assert cbs.stats["shed"] == 1 and cbs.stats["offered"] == 3
    assert cbs.queue_depth() == 2  # the shed request never enqueued
    handled = cbs.drain()
    assert len(handled) == 2
    assert cbs.stats["completed"] == 2


def test_mixed_rollout_depths_never_share_a_batch():
    eng = FakeEngine()
    cbs = sq.ContinuousBatchingServer(eng, buckets=eng.buckets,
                                      queue_limit=8,
                                      clock=sq.VirtualClock())
    x = np.zeros((1, 1), np.float32)
    for steps in (1, 1, 2, 2, 1):
        cbs.submit(x, rollout_steps=steps)
    cbs.drain()
    # FIFO forces the depth runs to dispatch as [1,1], [2,2], [1]
    assert eng.calls == [(2, 1), (2, 2), (1, 1)]
    assert cbs.stats["batches"] == 3 and cbs.stats["coalesced"] == 2


def test_engine_failure_marks_batch_failed_not_lost():
    eng = FakeEngine(fail=True)
    cbs = sq.ContinuousBatchingServer(eng, buckets=eng.buckets,
                                      queue_limit=4,
                                      clock=sq.VirtualClock())
    x = np.zeros((1, 1), np.float32)
    i0, i1 = cbs.submit(x), cbs.submit(x)
    handled = cbs.drain()
    assert {r.status for r in handled} == {"failed"}
    assert cbs.stats["failed"] == 2 and cbs.stats["completed"] == 0
    assert "injected engine failure" in cbs.result(i0).error
    assert cbs.result(i1).t_complete is not None  # terminal, accounted
    # conservation still holds with every request in a terminal state
    s = cbs.stats
    assert s["accepted"] == s["completed"] + s["deadline_exceeded"] + s["failed"]


def test_replay_requires_virtual_clock_and_model():
    eng = FakeEngine()
    cbs = sq.ContinuousBatchingServer(eng, buckets=eng.buckets)
    with pytest.raises(ValueError, match="VirtualClock"):
        cbs.replay([sq.Arrival(0.0, 1)], _payload)
    cbs = sq.ContinuousBatchingServer(eng, buckets=eng.buckets,
                                      clock=sq.VirtualClock())
    with pytest.raises(ValueError, match="service_model"):
        cbs.replay([sq.Arrival(0.0, 1)], _payload)


def test_poisson_schedule_is_seed_deterministic():
    a = sq.poisson_schedule(3, 16, rate_hz=100.0, max_n=4,
                            deadline_s=0.1)
    b = sq.poisson_schedule(3, 16, rate_hz=100.0, max_n=4,
                            deadline_s=0.1)
    assert a == b
    c = sq.poisson_schedule(4, 16, rate_hz=100.0, max_n=4, deadline_s=0.1)
    assert a != c
    assert all(x.t < y.t for x, y in zip(a, a[1:]))  # arrivals ordered


# ---------------------------------------------------------------------------
# the tier over the real fused engine (one small live pass)
# ---------------------------------------------------------------------------
def test_tier_over_real_server_matches_direct_calls():
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.core import fno as fno_mod
    from repro.train import serve_fno_step as sfs

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    server = sfs.FNOServer(cfg, params, max_batch=2)
    cbs = sq.ContinuousBatchingServer(server, queue_limit=4)
    assert cbs.buckets == server.buckets  # ladder discovered, not guessed
    key = jax.random.PRNGKey(1)
    xs = [np.asarray(jax.random.normal(
        jax.random.fold_in(key, i),
        (1 + i % 2, cfg.in_channels) + tuple(cfg.spatial)))
        for i in range(3)]
    idxs = [cbs.submit(x, rollout_steps=2) for x in xs]
    cbs.drain()
    # The tier batches but never changes math: each answer equals the
    # engine's own device-resident rollout on that request alone.
    for x, i in zip(xs, idxs):
        direct = np.asarray(server(np.asarray(x), rollout_steps=2))
        got = np.asarray(cbs.result(i).y)
        np.testing.assert_allclose(got, direct, rtol=0, atol=1e-6)
        assert np.isfinite(got).all()
