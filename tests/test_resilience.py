"""Resilience suite (ISSUE 9, docs/DESIGN.md §9): the deterministic
fault-injection harness, the guarded serving runtime's failure matrix
(degrade / failover / quarantine / reload-rollback / shed / deadline),
the hardened trainer (NaN budget, watchdog restart, ckpt save retry),
and the satellite fixes (pipeline timeout semantics, checkpointer
integrity sweep, watchdog one-shot)."""
import dataclasses
import os
import tempfile
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs import get_config
from repro.core import fno as fno_mod
from repro.data.pipeline import PrefetchPipeline
from repro.distributed import faults as flt
from repro.distributed.fault_tolerance import StragglerMonitor, Watchdog
from repro.train import serve_runtime as srt

PARITY_TOL = 2e-4


# ---------------------------------------------------------------------------
# FaultPlan: explicit, deterministic, fire-once
# ---------------------------------------------------------------------------
def test_fault_plan_take_fires_each_fault_once():
    plan = flt.FaultPlan([flt.Fault("kernel", at=3),
                          flt.Fault("nan", at=3)])
    got = plan.take("serve", 3, kind="kernel")
    assert [f.kind for f in got] == ["kernel"]
    assert plan.take("serve", 3, kind="kernel") == []  # fired = gone
    assert [f.kind for f in plan.pending()] == ["nan"]
    assert plan.take("train", 3, kind="nan") == []  # scope filter
    assert [f.kind for f in plan.take("serve", 3, kind="nan")] == ["nan"]
    assert plan.pending() == []


def test_fault_plan_replica_narrowing_and_count():
    plan = flt.FaultPlan([flt.Fault("kill", at=0, replica=1),
                          flt.Fault("kernel", at=0)])
    # A replica-pinned fault does not fire on a different replica...
    assert plan.take("serve", 0, kind="kill", replica=0) == []
    # ...but a replica-agnostic fault fires on whichever replica serves.
    assert len(plan.take("serve", 0, kind="kernel", replica=0)) == 1
    assert len(plan.take("serve", 0, kind="kill", replica=1)) == 1
    assert plan.count(kinds=("kill",)) == 1  # planned, not remaining
    assert plan.count() == 2


def test_fault_rejects_unknown_kind_and_scope():
    with pytest.raises(AssertionError):
        flt.Fault("meteor", at=0)
    with pytest.raises(AssertionError):
        flt.Fault("nan", at=0, scope="orbit")


def test_corrupt_checkpoint_defeats_verify_not_load():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, {"w": np.arange(6.0)})
        assert ck.verify(1)
        key = flt.corrupt_checkpoint(d, 1)
        assert key == "w"
        assert not ck.verify(1)  # checksum catches the flipped payload
        with pytest.raises(IOError):
            ck.restore(1, {"w": np.zeros(6)})


# ---------------------------------------------------------------------------
# satellite: PrefetchPipeline timeout semantics + terminal producer death
# ---------------------------------------------------------------------------
def test_pipeline_zero_timeout_is_a_timeout():
    # A slow producer + timeout=0 must poll (zero-second timeout), count
    # the misses as skips, and still return the batch once it lands —
    # the old code treated 0 as falsy "no timeout" and blocked.
    def slow(i):
        time.sleep(0.05)
        return {"x": i}

    pipe = PrefetchPipeline(slow, depth=1)
    try:
        idx, batch = pipe.get(timeout=0)
        assert batch == {"x": idx}
        # the 50ms producer latency showed up as Empty polls -> skips
        assert pipe.skipped >= 1
    finally:
        pipe.stop()


def test_pipeline_dead_producer_is_terminal():
    def dies(i):
        if i >= 2:
            raise ValueError("disk ate the shard")
        return {"x": i}

    pipe = PrefetchPipeline(dies, depth=1)
    try:
        assert pipe.get(timeout=1.0)[0] == 0
        assert pipe.get(timeout=1.0)[0] == 1
        with pytest.raises(RuntimeError, match="failed at index 2"):
            pipe.get(timeout=1.0)
        # Death is terminal: every later get raises IMMEDIATELY (the old
        # code spun on the empty queue counting skips forever).
        t0 = time.monotonic()
        with pytest.raises(RuntimeError, match="failed at index 2"):
            pipe.get(timeout=None)  # would hang forever pre-fix
        assert time.monotonic() - t0 < 0.5
    finally:
        pipe.stop()


# ---------------------------------------------------------------------------
# satellite: Checkpointer integrity — stale tmp sweep + latest_valid_step
# ---------------------------------------------------------------------------
def test_checkpointer_sweeps_stale_tmp_dirs():
    with tempfile.TemporaryDirectory() as d:
        stale = os.path.join(d, ".tmp_step_7")
        os.makedirs(stale)
        with open(os.path.join(stale, "arrays.npz"), "wb") as f:
            f.write(b"half-written garbage")
        Checkpointer(d)  # init sweeps crash leftovers
        assert not os.path.exists(stale)


def test_latest_valid_step_skips_corrupt_steps():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(1, {"w": np.ones(3)})
        ck.save(2, {"w": np.full(3, 2.0)})
        assert ck.latest_valid_step() == 2
        flt.corrupt_checkpoint(d, 2)
        assert ck.latest_step() == 2      # newest on disk...
        assert ck.latest_valid_step() == 1  # ...newest that verifies
        flt.corrupt_checkpoint(d, 1)
        assert ck.latest_valid_step() is None


# ---------------------------------------------------------------------------
# satellite: watchdog one-shot + straggler reset
# ---------------------------------------------------------------------------
def test_watchdog_fires_once_per_stall():
    fired = []
    wd = Watchdog(0.1, lambda: fired.append(time.monotonic()))
    try:
        time.sleep(0.6)  # one long stall, several checker periods
        assert len(fired) == 1, (
            f"one stall must fire exactly once, got {len(fired)}")
        wd.beat()  # re-arm
        time.sleep(0.4)
        assert len(fired) == 2
    finally:
        wd.stop()


def test_watchdog_beat_prevents_fire():
    fired = []
    wd = Watchdog(0.3, lambda: fired.append(1))
    try:
        for _ in range(6):
            time.sleep(0.05)
            wd.beat()
        assert fired == []
    finally:
        wd.stop()


def test_watchdog_callback_runs_outside_lock():
    # A callback that beats (like a self-restarting trainer might) must
    # not deadlock against the checker's lock.
    wd = None
    done = threading.Event()

    def cb():
        wd.beat()
        done.set()

    wd = Watchdog(0.1, cb)
    try:
        assert done.wait(2.0), "callback deadlocked on the watchdog lock"
    finally:
        wd.stop()


def test_straggler_monitor_reset():
    m = StragglerMonitor(ratio=2.0, decay=0.5)
    for s in range(5):
        m.record(s, 0.1)
    assert m.record(5, 0.5) is True
    m.reset()
    assert m.ema is None and m.flagged == []
    # Post-reset, a slow first step is baseline, not a straggler.
    assert m.record(6, 0.5) is False


# ---------------------------------------------------------------------------
# ResilientServer failure matrix (reduced fno2d, pallas interpret on CPU)
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def serve_setup():
    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    x = jax.random.normal(jax.random.fold_in(key, 1),
                          (2, cfg.in_channels) + tuple(cfg.spatial))
    oracle = np.asarray(fno_mod.apply_fno(params, cfg, x, path="xla"))
    return cfg, params, x, oracle


def _server(serve_setup, plan=None, **kw):
    cfg, params, _, _ = serve_setup
    kw.setdefault("replicas", 2)
    kw.setdefault("max_batch", 2)
    kw.setdefault("backoff_base_s", 1e-3)
    return srt.ResilientServer(cfg, params, fault_plan=plan, **kw)


def test_kernel_fault_degrades_to_xla_with_parity(serve_setup):
    _, _, x, oracle = serve_setup
    plan = flt.FaultPlan([flt.Fault("kernel", at=0)])
    rs = _server(serve_setup, plan)
    y = rs(x)
    assert np.isfinite(y).all()
    assert float(np.max(np.abs(y - oracle))) <= PARITY_TOL
    assert rs.stats["degraded"] == 1 and rs.stats["quarantined"] == 1
    # drain's health sweep gave the quarantined replica its canary back
    assert rs.stats["reinstated"] == 1
    assert rs.pool.states() == {"healthy": 2, "quarantined": 0, "dead": 0}


def test_nan_output_quarantines_and_reserves(serve_setup):
    _, _, x, oracle = serve_setup
    plan = flt.FaultPlan([flt.Fault("nan", at=0)])
    rs = _server(serve_setup, plan)
    y = rs(x)  # the poisoned reply is caught, re-served on XLA
    assert np.isfinite(y).all()
    assert float(np.max(np.abs(y - oracle))) <= PARITY_TOL
    assert rs.stats["degraded"] == 1
    assert rs.stats["served"] == 1 and rs.stats["accepted"] == 1


def test_replica_kill_fails_over_with_zero_drops(serve_setup):
    _, _, x, _ = serve_setup
    plan = flt.FaultPlan([flt.Fault("kill", at=0)])
    rs = _server(serve_setup, plan)
    for _ in range(3):
        rs.submit(x)
    ys = rs.drain()
    assert len(ys) == 3 and all(np.isfinite(y).all() for y in ys)
    assert rs.stats["killed"] == 1 and rs.stats["failovers"] == 1
    assert rs.stats["retries"] == 1
    assert rs.stats["degraded"] == 0  # failover is not degradation
    assert rs.pool.states()["dead"] == 1  # kills are terminal


def test_all_replicas_dead_raises_no_healthy(serve_setup):
    _, _, x, _ = serve_setup
    # Pin one kill to each replica id: whichever replica the failover
    # retries onto dies too, exhausting the pool.
    plan = flt.FaultPlan([flt.Fault("kill", at=0, replica=0),
                          flt.Fault("kill", at=0, replica=1)])
    rs = _server(serve_setup, plan)
    with pytest.raises(srt.NoHealthyReplica):
        rs(x)
    assert rs.pool.states()["dead"] == 2
    assert rs.stats["killed"] == 2


def test_admission_overflow_sheds_explicitly(serve_setup):
    _, _, x, _ = serve_setup
    rs = _server(serve_setup, queue_limit=2)
    rs.submit(x)
    rs.submit(x)
    with pytest.raises(srt.RequestRejected):
        rs.submit(x)
    assert rs.stats["accepted"] == 2 and rs.stats["shed"] == 1
    ys = rs.drain()  # the admitted two still get answers
    assert len(ys) == 2 and all(np.isfinite(y).all() for y in ys)


def test_deadline_exceeded_on_injected_delay(serve_setup):
    _, _, x, _ = serve_setup
    plan = flt.FaultPlan([flt.Fault("delay", at=0, delay_s=0.3)])
    rs = _server(serve_setup, plan, deadline_s=0.05)
    with pytest.raises(srt.DeadlineExceeded):
        rs(x)
    assert rs.stats["deadline_exceeded"] == 1
    assert rs.stats["served"] == 0


def test_reload_rolls_back_on_corrupt_checkpoint(serve_setup):
    cfg, params, x, _ = serve_setup
    params2 = fno_mod.init_fno(jax.random.PRNGKey(7), cfg)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        rs = _server(serve_setup, checkpointer=ck)
        before = rs(x)
        ck.save(1, params2)
        flt.corrupt_checkpoint(d, 1)
        assert rs.reload() is False  # latest_valid_step finds nothing
        assert rs.stats["rollbacks"] == 1 and rs.stats["reloads"] == 0
        after = rs(x)  # old params keep serving, bit-identical
        np.testing.assert_array_equal(before, after)


def test_reload_swaps_on_valid_checkpoint(serve_setup):
    cfg, params, x, _ = serve_setup
    params2 = fno_mod.init_fno(jax.random.PRNGKey(7), cfg)
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        rs = _server(serve_setup, checkpointer=ck)
        ck.save(1, params2)  # corrupt step...
        flt.corrupt_checkpoint(d, 1)
        ck.save(2, params2)  # ...shadowed by a newer valid one
        assert rs.reload() is True
        assert rs.stats["reloads"] == 1
        want = np.asarray(fno_mod.apply_fno(params2, cfg, x, path="xla"))
        assert float(np.max(np.abs(rs(x) - want))) <= PARITY_TOL


def test_standard_chaos_plan_end_to_end(serve_setup):
    # The CI gate's plan, compressed: kernel + nan + kill across the
    # first three requests — every accepted request answered finite,
    # degradations exactly the planned count.
    _, _, x, _ = serve_setup
    plan = flt.standard_chaos_plan()
    rs = _server(serve_setup, plan)
    for _ in range(4):
        rs.submit(x)
    ys = rs.drain()
    assert len(ys) == 4 and all(np.isfinite(y).all() for y in ys)
    assert rs.stats["degraded"] == plan.count(kinds=("kernel", "nan"))
    assert rs.stats["killed"] == 1
    # the corrupt_ckpt record is a driver fault, never consumed in-band
    assert [f.kind for f in plan.pending()] == ["corrupt_ckpt"]


@pytest.mark.parametrize("name", sorted(flt.canned_chaos_plans()))
def test_stat_keys_conserve_under_every_canned_plan(serve_setup, name):
    # ISSUE 10 satellite: whatever a canned chaos plan injects, the
    # counter ledger must balance — no request may vanish from the stats,
    # and the fault-class counters must match the plan EXACTLY (counts
    # are injected deterministically, so anything else is an accounting
    # bug, not noise).
    _, _, x, _ = serve_setup
    plan = flt.canned_chaos_plans()[name]
    planned_degrade = plan.count(kinds=("kernel", "nan"))
    planned_kill = plan.count(kinds=("kill",))
    rs = _server(serve_setup, plan)
    offered = 5
    for _ in range(offered):
        rs.submit(x)
    ys = rs.drain()
    s = rs.stats
    assert set(s) == set(srt.ResilientServer.STAT_KEYS)
    # admission ledger: every offered request is accepted (no shed here)
    # and every accepted request reached exactly one terminal outcome.
    assert s["accepted"] == offered and s["shed"] == 0
    assert s["served"] + s["deadline_exceeded"] == s["accepted"]
    assert len(ys) == offered and all(np.isfinite(y).all() for y in ys)
    # fault-class counters match the plan exactly.
    assert s["degraded"] == planned_degrade, (name, dict(s))
    assert s["killed"] == planned_kill, (name, dict(s))
    assert s["failovers"] == planned_kill  # every kill failed over
    # quarantine is a cycle: drain's health sweep reinstates whatever the
    # faults quarantined, so the pool ends with no quarantined replica
    # and the two counters agree.
    assert s["quarantined"] == s["reinstated"], (name, dict(s))
    assert rs.pool.states()["quarantined"] == 0
    assert rs.pool.states()["dead"] == planned_kill
    # nothing in these plans touches checkpoints in-band.
    assert s["reloads"] == 0 and s["rollbacks"] == 0


def test_stat_keys_conserve_under_forced_shed(serve_setup):
    # The shed path joins the same ledger: offered == accepted + shed,
    # with the exact shed count forced by the admission bound.
    _, _, x, _ = serve_setup
    rs = _server(serve_setup, queue_limit=3)
    offered, shed = 5, 2
    for i in range(offered):
        if i < 3:
            rs.submit(x)
        else:
            with pytest.raises(srt.RequestRejected):
                rs.submit(x)
    ys = rs.drain()
    s = rs.stats
    assert s["accepted"] + s["shed"] == offered
    assert s["shed"] == shed
    assert s["served"] == s["accepted"] == len(ys) == 3


# ---------------------------------------------------------------------------
# hardened trainer: NaN budget, ckpt save retry, watchdog restart
# ---------------------------------------------------------------------------
def _mk_trainer(d, steps=8, plan=None, **cfg_kw):
    from repro.data import pde
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.train_step import make_train_step
    from repro.train.trainer import Trainer, TrainerConfig

    cfg = get_config("fno1d", reduced=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    opt = AdamW(lr=constant(1e-3))
    step = jax.jit(make_train_step(cfg, opt, fno_path="xla"))
    batch_fn = lambda i: pde.burgers_batch(0, i, 4, cfg.spatial[0])
    tc = TrainerConfig(total_steps=steps, ckpt_every=4, ckpt_dir=d,
                       log_every=2, ckpt_async=False, **cfg_kw)
    return Trainer(tc, step, batch_fn, params, opt_state=opt.init(params),
                   fault_plan=plan)


def test_trainer_skips_nan_steps_within_budget():
    from repro.train.trainer import NaNBudgetExceeded  # noqa: F401

    plan = flt.FaultPlan([flt.Fault("nan", at=2, scope="train")])
    with tempfile.TemporaryDirectory() as d:
        tr = _mk_trainer(d, steps=8, plan=plan, nan_skip_budget=2)
        before = jax.tree_util.tree_map(np.asarray, tr.params)
        out = tr.run()
        assert out["final_step"] == 8
        assert out["nan_skipped"] == 1
        # the poisoned update was DISCARDED: params kept evolving from
        # clean steps only (they must differ from init — training ran)
        leaves = jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(np.asarray, tr.params))
        assert all(np.isfinite(l).all() for l in leaves)
        init_leaves = jax.tree_util.tree_leaves(before)
        assert any(not np.array_equal(a, b)
                   for a, b in zip(leaves, init_leaves))


def test_trainer_nan_budget_exceeded_raises_not_restarts():
    from repro.train.trainer import NaNBudgetExceeded

    plan = flt.FaultPlan([flt.Fault("nan", at=s, scope="train")
                          for s in (1, 2, 3)])
    with tempfile.TemporaryDirectory() as d:
        tr = _mk_trainer(d, steps=8, plan=plan, nan_skip_budget=2)
        # run_with_restarts must surface it, NOT restart (deterministic
        # data would replay the poison forever)
        with pytest.raises(NaNBudgetExceeded):
            tr.run_with_restarts()
        assert tr.restarts == 0
        assert tr.nan_skipped == 3


def test_trainer_ckpt_save_retries_on_injected_io_fault():
    plan = flt.FaultPlan([flt.Fault("ckpt_io", at=4, scope="train")])
    with tempfile.TemporaryDirectory() as d:
        tr = _mk_trainer(d, steps=8, plan=plan, ckpt_retries=2,
                         ckpt_backoff_s=0.01)
        out = tr.run()
        assert out["final_step"] == 8
        assert out["ckpt_save_retries"] == 1  # one fault, one retry
        assert tr.ckpt.latest_valid_step() == 8


def test_trainer_watchdog_timeout_triggers_restart():
    from repro.train.trainer import WatchdogTimeout  # noqa: F401

    plan = flt.FaultPlan([flt.Fault("delay", at=5, scope="train",
                                    delay_s=1.5)])
    with tempfile.TemporaryDirectory() as d:
        tr = _mk_trainer(d, steps=8, plan=plan, step_timeout_s=0.3)
        # Warm the jit cache first: compile time must not read as a stall
        # (in production step_timeout_s is sized well above compile).
        b = tr.batch_fn(0)
        jax.block_until_ready(
            tr.train_step(tr.params, tr.opt_state, b)[2]["loss"])
        out = tr.run_with_restarts()
        # the stalled step fired the watchdog -> WatchdogTimeout -> one
        # restart from the step-4 checkpoint -> run completes
        assert tr.restarts == 1
        assert out["final_step"] == 8
        assert tr.ckpt.latest_valid_step() == 8


def test_trainer_restores_through_corrupt_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        tr = _mk_trainer(d, steps=8)
        tr._fail_at = {6: RuntimeError("node died")}
        # corrupt the step-4 checkpoint as soon as it lands: the restart
        # must skip it (latest_valid_step) and fall back to the newest
        # valid state — here from scratch — instead of crashing mid-restore
        orig_save = tr._save_ckpt

        def save_and_corrupt(step):
            orig_save(step)
            if step == 4:
                flt.corrupt_checkpoint(d, 4)

        tr._save_ckpt = save_and_corrupt
        out = tr.run_with_restarts()
        assert tr.restarts == 1
        assert out["final_step"] == 8


# ---------------------------------------------------------------------------
# DP-sharded resilient serving on the forced-8-device mesh
# ---------------------------------------------------------------------------
def test_resilient_server_on_dp_mesh(subproc):
    subproc("""
    import sys
    sys.path.insert(0, {src!r})
    import dataclasses
    import jax
    import numpy as np
    from repro.configs import get_config
    from repro.core import fno as fno_mod
    from repro.distributed import faults as flt
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_compat_mesh
    from repro.train import serve_runtime as srt

    assert jax.device_count() == 8
    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    mesh = make_compat_mesh((4, 2), ("data", "model"))
    ctx = shd.make_context(cfg, mesh, kind="serve")
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    plan = flt.FaultPlan([flt.Fault("kernel", at=0),
                          flt.Fault("kill", at=1)])
    rs = srt.ResilientServer(cfg, params, ctx=ctx, replicas=2,
                             max_batch=4, fault_plan=plan,
                             backoff_base_s=1e-3)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (3, cfg.in_channels) + tuple(cfg.spatial))
    oracle = np.asarray(fno_mod.apply_fno(params, cfg, x, path="xla"))
    for _ in range(3):
        rs.submit(x)
    ys = rs.drain()
    assert len(ys) == 3
    for y in ys:
        assert np.isfinite(y).all()
        assert float(np.max(np.abs(y - oracle))) <= 2e-4
    assert rs.stats["degraded"] == 1 and rs.stats["killed"] == 1
    assert rs.stats["failovers"] == 1
    print("dp-mesh resilient serve OK:", rs.pool_report())
    """.format(src=os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")))
