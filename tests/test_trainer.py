"""Trainer fault-tolerance: failure injection + restart, straggler
monitor, watchdog."""
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import fno as fno_mod
from repro.data import pde
from repro.distributed.fault_tolerance import StragglerMonitor, Watchdog
from repro.optim import AdamW
from repro.optim.schedule import constant
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def _mk_trainer(d, fail_at=None, steps=12):
    cfg = get_config("fno1d", reduced=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    opt = AdamW(lr=constant(1e-3))
    step = jax.jit(make_train_step(cfg, opt, fno_path="xla"))
    batch_fn = lambda i: pde.burgers_batch(0, i, 4, cfg.spatial[0])
    tc = TrainerConfig(total_steps=steps, ckpt_every=4, ckpt_dir=d,
                       log_every=2, ckpt_async=False)
    return Trainer(tc, step, batch_fn, params, opt_state=opt.init(params),
                   fail_at=fail_at)


def test_restart_resumes_from_checkpoint():
    with tempfile.TemporaryDirectory() as d:
        tr = _mk_trainer(d, fail_at={6: RuntimeError("node died")})
        out = tr.run_with_restarts()
        assert tr.restarts == 1
        assert out["final_step"] == 12
        # checkpoints exist and last one is final
        assert tr.ckpt.latest_step() == 12


def test_restart_gives_same_result_as_uninterrupted():
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        tr_fail = _mk_trainer(d1, fail_at={5: RuntimeError("x")}, steps=8)
        tr_fail.run_with_restarts()
        tr_ok = _mk_trainer(d2, steps=8)
        tr_ok.run()
        # both end at step 8; params from checkpoints must match exactly
        # (deterministic data + restart from step-4 checkpoint replays 4..8)
        a = tr_fail.ckpt.restore(8, {"params": tr_fail.params,
                                     "opt": tr_fail.opt_state})
        b = tr_ok.ckpt.restore(8, {"params": tr_ok.params,
                                   "opt": tr_ok.opt_state})
        import numpy as np
        jax.tree_util.tree_map(
            lambda x, y: np.testing.assert_allclose(
                np.asarray(x), np.asarray(y), rtol=1e-6, atol=1e-6),
            a, b)


def test_straggler_monitor():
    m = StragglerMonitor(ratio=2.0, decay=0.5)
    for s in range(5):
        m.record(s, 0.1)
    assert m.record(5, 0.5) is True
    assert m.flagged == [5]
    assert m.record(6, 0.1) is False


def test_watchdog_fires():
    fired = []
    wd = Watchdog(0.2, lambda: fired.append(1))
    time.sleep(0.5)
    wd.stop()
    assert fired
