"""Mamba2/SSD: chunked forward vs naive recurrence; chunk-size invariance."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import ssm as ssm_mod


def _setup(seed=0):
    cfg = get_config("mamba2-370m", reduced=True)
    key = jax.random.PRNGKey(seed)
    p = ssm_mod.ssm_init(key, cfg, jnp.float32)
    return cfg, p, key


@pytest.mark.parametrize("s", [16, 32, 48])
def test_chunked_equals_recurrence(s):
    cfg, p, key = _setup()
    x = 0.5 * jax.random.normal(key, (2, s, cfg.d_model), jnp.float32)
    y_chunk, (conv_f, h_f) = ssm_mod.ssd_forward(p, x, cfg,
                                                 return_state=True)
    state = (jnp.zeros((2, cfg.ssm_conv_width - 1, cfg.d_inner)),
             jnp.zeros((2, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim)))
    ys = []
    for t in range(s):
        y, state = ssm_mod.ssd_decode_step(p, x[:, t:t + 1], state, cfg)
        ys.append(y)
    y_naive = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_naive),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_f), np.asarray(state[1]),
                               rtol=1e-4, atol=1e-4)


def test_chunk_size_invariance():
    cfg, p, key = _setup(1)
    x = 0.5 * jax.random.normal(key, (1, 64, cfg.d_model), jnp.float32)
    outs = []
    for q in (8, 16, 32, 64):
        c = dataclasses.replace(cfg, ssm_chunk=q)
        outs.append(ssm_mod.ssd_forward(p, x, c))
    for o in outs[1:]:
        np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(o),
                                   rtol=1e-4, atol=1e-4)


def test_state_causality():
    """Output at position t is independent of future inputs."""
    cfg, p, key = _setup(2)
    x = jax.random.normal(key, (1, 32, cfg.d_model), jnp.float32)
    y1 = ssm_mod.ssd_forward(p, x, cfg)
    x2 = x.at[:, 20:].set(99.0)
    y2 = ssm_mod.ssd_forward(p, x2, cfg)
    np.testing.assert_allclose(np.asarray(y1[:, :20]),
                               np.asarray(y2[:, :20]), rtol=1e-5, atol=1e-5)
    assert float(jnp.abs(y1[:, 20:] - y2[:, 20:]).max()) > 1e-3
