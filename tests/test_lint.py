"""Contract-linter tests (ISSUE 6): the clean repo passes every checker,
and mutation-style fixtures that deliberately violate each contract make
exactly the targeted checker fire with a pointed message."""
import os
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import ast_lint, errors, jaxpr_lint, vmem
from repro.configs import get_config
from repro.configs.base import FNOConfig, PrecisionPolicy
from repro.kernels import ops

MODES2 = (3, 4)


def _block_args(dtype="f32"):
    return jaxpr_lint.block_args(2, "shared", dtype)


def _block(policy):
    return lambda *a: ops.fno_block_nd(*a, MODES2, path="pallas",
                                       variant="full", policy=policy)


# ---------------------------------------------------------------------------
# clean repo: every layer passes
# ---------------------------------------------------------------------------
def test_ast_lints_clean_on_repo():
    assert ast_lint.run_ast_lints() == []


def test_config_registry_clean():
    assert ast_lint.check_config_registry() == []


def test_block_matrix_subset_clean():
    fs = jaxpr_lint.lint_block_matrix(ranks=(2,), layouts=("shared",),
                                      variants=("full",), dtypes=("f32",))
    assert fs == []


def test_fused_block_contract_wrapper_clean():
    assert jaxpr_lint.fused_block_contract() == []


def test_vmem_reduced_configs_fit():
    cfgs = [(get_config(a, reduced=True), True)
            for a in ("fno1d", "fno2d", "fno3d")]
    assert errors(vmem.check_vmem(configs=cfgs)) == []


def test_vmem_full_size_configs_clean_with_tuned_cache():
    # Since the tuned cache (ISSUE 7), EVERY config — the big full-size
    # grids included — must resolve a budget-feasible plan at ERROR
    # severity. fno3d is the stress case: its x windows alone forced the
    # static defaults ~9x over budget before tuning.
    fs = vmem.check_vmem(configs=[get_config("fno3d")])
    assert fs == [], fs


def test_vmem_errors_without_tuned_cache(monkeypatch):
    # Mutation: with the cache gone, resolution falls back to the static
    # defaults, which overflow VMEM on the full-size 3D grid — the
    # checker must fire at error severity (the pre-tuning 42-warning
    # state is no longer tolerated).
    from repro.tuning import store

    monkeypatch.setattr(store, "load_cache",
                        lambda path=None: {"meta": {}, "entries": {}})
    fs = vmem.check_vmem(configs=[get_config("fno3d")], dtypes=("f32",),
                         variants=("full",))
    assert fs and errors(fs)
    assert any("regenerate the cache" in f.message for f in fs)


def test_sharded_and_serve_lints_clean(subproc):
    subproc("""
    import sys
    sys.path.insert(0, {src!r})
    from repro.analysis import format_findings, jaxpr_lint
    fs = jaxpr_lint.lint_sharded_blocks(mesh_grids=((4, 2), (8, 1)),
                                        dtypes=("f32",))
    fs += jaxpr_lint.lint_serve(mesh_grids=((4, 2),), dtypes=("f32",))
    assert not fs, format_findings(fs)
    print("sharded+serve lints OK")
    """.format(src=os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")))


# ---------------------------------------------------------------------------
# trace-lint mutations: each contract violation makes its checker fire
# ---------------------------------------------------------------------------
def test_mutation_split_pallas_call_fires_count_checker():
    pol = PrecisionPolicy.from_name("f32")
    blk = _block(pol)
    args = _block_args()

    def doubled(*a):  # a second kernel launch where the contract wants one
        return blk(*a) + blk(*a)

    fs = jaxpr_lint.check_pallas_count(doubled, args, 1, target="mutant")
    assert len(fs) == 1 and fs[0].checker == "pallas-count"
    assert "traced 2 pallas_calls, want exactly 1" in fs[0].message
    # the clean block passes the same checker
    assert jaxpr_lint.check_pallas_count(blk, args, 1, target="ok") == []


def test_mutation_stray_cast_fires_cast_checker():
    pol = PrecisionPolicy.from_name("f32")
    blk = _block(pol)
    args = _block_args()

    def leaky(*a):  # a stray down-cast the f32 policy does not own
        return blk(*a).astype(jnp.bfloat16)

    fs = jaxpr_lint.check_cast_ownership(leaky, args, pol, target="mutant")
    assert len(fs) == 1 and fs[0].checker == "cast-ownership"
    assert "float32->bfloat16" in fs[0].message
    assert jaxpr_lint.check_cast_ownership(blk, args, pol, target="ok") == []


def test_bf16_policy_allows_its_boundary_casts():
    pol = PrecisionPolicy.from_name("bf16")
    blk = _block(pol)
    args = jaxpr_lint.block_args(2, "shared", "bf16")
    assert jaxpr_lint.check_cast_ownership(blk, args, pol, target="ok") == []


def test_mutation_doubled_psum_fires_collective_checker():
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import compat_shard_map
    from repro.launch.mesh import make_compat_mesh

    mesh = make_compat_mesh((1, 1), ("data", "model"))
    x = jnp.zeros((4, 4))

    def once(xl):
        return jax.lax.psum(xl, "model")

    def twice(xl):  # one psum over budget
        return jax.lax.psum(jax.lax.psum(xl, "model"), "model")

    fn1 = compat_shard_map(once, mesh, in_specs=(P(),), out_specs=P())
    fn2 = compat_shard_map(twice, mesh, in_specs=(P(),), out_specs=P())
    assert jaxpr_lint.check_collective_budget(fn1, (x,), psums=1,
                                              target="ok") == []
    fs = jaxpr_lint.check_collective_budget(fn2, (x,), psums=1,
                                            target="mutant")
    assert len(fs) == 1 and fs[0].checker == "collective-budget"
    assert "traced 2 psum(s), want exactly 1" in fs[0].message


def test_mutation_foreign_collective_fires_collective_checker():
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import compat_shard_map
    from repro.launch.mesh import make_compat_mesh

    mesh = make_compat_mesh((1,), ("data",))
    x = jnp.zeros((4, 4))

    def gathers(xl):
        return jax.lax.all_gather(xl, "data")

    fn = compat_shard_map(gathers, mesh, in_specs=(P(),), out_specs=P(None))
    fs = jaxpr_lint.check_collective_budget(fn, (x,), psums=0,
                                            target="mutant")
    assert len(fs) == 1
    assert "all_gather" in fs[0].message


def test_mutation_scatter_budget_fires_both_ways():
    # ISSUE 8: the scattered-layout budget (psum_scatters per interior
    # layer, a single final psum). An interior layer that all-reduces
    # instead of scattering fires BOTH messages: one psum over budget,
    # one psum_scatter missing.
    from jax.sharding import PartitionSpec as P

    from repro.distributed.sharding import compat_shard_map
    from repro.launch.mesh import make_compat_mesh

    mesh = make_compat_mesh((1, 1), ("data", "model"))
    x = jnp.zeros((4, 4))

    def scatters(xl):
        return jax.lax.psum_scatter(xl, "model", scatter_dimension=0,
                                    tiled=True)

    def psums(xl):  # the psum layout leaking into a scattered budget
        return jax.lax.psum(xl, "model")

    ok = compat_shard_map(scatters, mesh, in_specs=(P(),),
                          out_specs=P("model"))
    bad = compat_shard_map(psums, mesh, in_specs=(P(),), out_specs=P())
    assert jaxpr_lint.check_collective_budget(
        ok, (x,), psums=0, psum_scatters=1, target="ok") == []
    fs = jaxpr_lint.check_collective_budget(
        bad, (x,), psums=0, psum_scatters=1, target="mutant")
    assert len(fs) == 2 and all(
        f.checker == "collective-budget" for f in fs)
    msgs = " | ".join(f.message for f in fs)
    assert "traced 1 psum(s), want exactly 0" in msgs
    assert "traced 0 psum_scatter(s), want exactly 1" in msgs


def test_resilient_serve_lint_clean_and_mutation():
    # ISSUE 9: the resilience trace contract. Clean: the ResilientServer
    # production step traces exactly num_layers pallas_calls while the
    # degraded XLA step traces ZERO. Mutation: a "fallback" that launches
    # the pallas path itself (defeating the whole point of degradation)
    # makes the degraded-step checker fire.
    import dataclasses

    from repro.core import fno as fno_mod
    from repro.train import serve_runtime as srt

    fs = jaxpr_lint.lint_resilient_serve(dtypes=("f32",))
    assert fs == [], fs

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    params = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        jax.eval_shape(lambda: fno_mod.init_fno(jax.random.PRNGKey(0),
                                                cfg)))
    rs = srt.ResilientServer(cfg, params, replicas=1, max_batch=2)
    xb = jnp.zeros((rs.primary.buckets[0], cfg.in_channels)
                   + tuple(cfg.spatial), jnp.float32)
    args = (params, {"x": xb})

    def kernel_launching_fallback(p, batch):  # the mutant degraded step
        return rs.primary.step_fn(p, batch)

    fs = jaxpr_lint.check_pallas_count(kernel_launching_fallback, args, 0,
                                       target="mutant fallback")
    assert len(fs) == 1 and fs[0].checker == "pallas-count"
    assert (f"traced {cfg.num_layers} pallas_calls, want exactly 0"
            in fs[0].message)


def _rollout_fixture():
    """Reduced fno2d fused server with zero params (tracing only — no
    kernels execute) plus a bucket-sized batch, for the rollout lints."""
    import dataclasses

    from repro.core import fno as fno_mod
    from repro.train import serve_fno_step as sfs

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    params = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        jax.eval_shape(lambda: fno_mod.init_fno(jax.random.PRNGKey(0),
                                                cfg)))
    server = sfs.FNOServer(cfg, params, max_batch=2)
    xb = jnp.zeros((server.buckets[0], cfg.in_channels)
                   + tuple(cfg.spatial), jnp.float32)
    return cfg, server, (params, {"x": xb})


def test_rollout_lint_clean_and_depth_invariant():
    # ISSUE 10: the rollout trace contract. The device-resident K-step
    # rollout is ONE lax.scan whose body traces once, so the pallas_call
    # count stays exactly num_layers for ANY depth — pinned here for the
    # acceptance K in {1, 4} via the sweep entry point AND the raw
    # checker. ``steps`` must be bound statically (functools.partial)
    # before tracing: a traced depth would abstract the scan length.
    import functools

    fs = jaxpr_lint.lint_rollout(archs=("fno2d",), dtypes=("f32",),
                                 ks=(1, 4))
    assert fs == [], fs

    cfg, server, args = _rollout_fixture()
    for k in (1, 4):
        fn = functools.partial(server.rollout_step_fn, steps=k)
        assert jaxpr_lint.check_pallas_count(
            fn, args, cfg.num_layers, target=f"rollout K={k}") == []
        assert jaxpr_lint.check_cast_ownership(
            fn, args, cfg.precision, target=f"rollout K={k}") == []


def test_mutation_unrolled_rollout_fires_count_checker():
    # The mutant the contract exists to kill: a python-loop rollout
    # re-traces the whole network every step, so K=4 launches
    # K * num_layers kernels (and recompiles per depth). The count
    # checker must fire with the exact inflated count.
    from repro.core import fno as fno_mod

    cfg, _, args = _rollout_fixture()

    def unrolled(p, batch):  # the staged loop masquerading as a rollout
        x = batch["x"]
        for _ in range(4):
            y = fno_mod.apply_fno(p, cfg, x, path="pallas")
            x = jnp.concatenate([y, x[:, cfg.out_channels:].astype(y.dtype)],
                                axis=1)
        return x[:, :cfg.out_channels]

    fs = jaxpr_lint.check_pallas_count(unrolled, args, cfg.num_layers,
                                       target="unrolled rollout")
    assert len(fs) == 1 and fs[0].checker == "pallas-count"
    assert (f"traced {4 * cfg.num_layers} pallas_calls, want exactly "
            f"{cfg.num_layers}" in fs[0].message)


def test_mutation_psum_layout_fails_scatter_budget(subproc):
    # End-to-end mutation on the REAL serve path: hold the legacy psum
    # layout to the scattered layout's budget — both messages fire
    # (num_layers psums where 1 is allowed, zero interior scatters).
    subproc("""
    import sys
    sys.path.insert(0, {src!r})
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.core import fno as fno_mod
    from repro.analysis import jaxpr_lint as jl

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True,
                              tp_layout="psum")
    params = jax.tree_util.tree_map(
        lambda s: jnp.zeros(s.shape, s.dtype),
        jax.eval_shape(lambda: fno_mod.init_fno(jax.random.PRNGKey(0),
                                                cfg)))
    x = jnp.zeros((8, cfg.in_channels) + tuple(cfg.spatial))
    ctx = shd.make_context(cfg, make_debug_mesh(4, 2), kind="serve")
    def fwd(p, xx):
        with shd.sharding_context(ctx):
            return fno_mod.apply_fno(p, cfg, xx, path="pallas")
    L = cfg.num_layers
    fs = jl.check_collective_budget(fwd, (params, x), psums=1,
                                    psum_scatters=L - 1, target="mutant")
    assert len(fs) == 2, fs
    msgs = " | ".join(f.message for f in fs)
    assert f"traced {{L}} psum(s), want exactly 1" in msgs, msgs
    assert f"traced 0 psum_scatter(s), want exactly {{L - 1}}" in msgs, msgs
    print("psum-layout-vs-scattered-budget mutation OK")
    """.format(src=os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")))


# ---------------------------------------------------------------------------
# AST-lint mutations (tmp files, scanned with the tmp dir as root)
# ---------------------------------------------------------------------------
def _lint_snippet(tmp_path, rel, code):
    path = tmp_path / rel
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(textwrap.dedent(code))
    return ast_lint.run_ast_lints(root=tmp_path)


def test_mutation_raw_shard_map_import_fires(tmp_path):
    fs = _lint_snippet(tmp_path, "distributed/rogue.py", """
        from jax.experimental.shard_map import shard_map
        """)
    assert len(fs) == 1 and fs[0].checker == "compat-shard-map"
    assert "compat_shard_map" in fs[0].message
    assert fs[0].target == "distributed/rogue.py:2"


def test_shard_map_home_is_exempt(tmp_path):
    fs = _lint_snippet(tmp_path, "distributed/sharding.py", """
        from jax.experimental.shard_map import shard_map
        """)
    assert fs == []


def test_mutation_bare_pallas_call_fires(tmp_path):
    fs = _lint_snippet(tmp_path, "kernels/rogue.py", """
        import jax
        from jax.experimental import pallas as pl

        def call(x):
            return pl.pallas_call(lambda i, o: None, grid=(1,),
                                  out_shape=x)(x)
        """)
    assert len(fs) == 1 and fs[0].checker == "pallas-compiler-params"
    assert "_compiler_params" in fs[0].message


def test_pallas_call_through_shim_passes(tmp_path):
    fs = _lint_snippet(tmp_path, "kernels/fine.py", """
        from jax.experimental import pallas as pl
        from repro.kernels import _compiler_params

        def call(x):
            return pl.pallas_call(
                lambda i, o: None, grid=(1,), out_shape=x,
                compiler_params=_compiler_params(
                    dimension_semantics=("parallel",)))(x)
        """)
    assert fs == []


def test_mutation_raw_fft_fires(tmp_path):
    fs = _lint_snippet(tmp_path, "kernels/rogue_fft.py", """
        import jax.numpy as jnp

        def fwd(x):
            return jnp.fft.rfft(x, axis=-1)
        """)
    assert len(fs) == 1 and fs[0].checker == "no-raw-fft"


def test_mutation_dtype_literal_fires_and_pragma_allows(tmp_path):
    bad = _lint_snippet(tmp_path, "kernels/ops.py", """
        import jax.numpy as jnp

        def sneaky(x):
            return x.astype(jnp.float32)
        """)
    assert len(bad) == 1 and bad[0].checker == "dtype-literal"
    assert "sneaky" in bad[0].message

    ok = _lint_snippet(tmp_path, "kernels/ops.py", """
        import jax.numpy as jnp

        def sneaky(x):
            return x.astype(jnp.float32)  # lint: allow-dtype
        """)
    assert ok == []


def test_dtype_literal_ignored_outside_scope(tmp_path):
    fs = _lint_snippet(tmp_path, "models/free.py", """
        import jax.numpy as jnp

        def fine(x):
            return x.astype(jnp.float32)
        """)
    assert fs == []


# ---------------------------------------------------------------------------
# registry + vmem mutations
# ---------------------------------------------------------------------------
def test_mutation_registry_gap_fires(monkeypatch):
    import repro.configs as configs

    real = list(configs.runnable_cells())

    def with_empty_reason():  # skipped cell with a blank reason
        yield from real[:-1]
        a, s, _ = real[-1]
        yield a, s, "   "

    monkeypatch.setattr(configs, "runnable_cells", with_empty_reason)
    fs = ast_lint.check_config_registry()
    assert any(f.checker == "config-registry" and "EMPTY" in f.message
               for f in fs)

    def missing_arch():  # an arch the grid never enumerates
        yield from (row for row in real if row[0] != "fno2d-large")

    monkeypatch.setattr(configs, "runnable_cells", missing_arch)
    fs = ast_lint.check_config_registry()
    assert any(f.target == "fno2d-large"
               and "never enumerated" in f.message for f in fs)


def test_mutation_oversized_launch_fires_vmem_checker():
    big = FNOConfig(name="fno2d-absurd", ndim=2, hidden=512, num_layers=1,
                    in_channels=1, out_channels=1, spatial=(256, 256),
                    modes=(64, 64), weight_mode="per_mode")
    fs = vmem.check_vmem(configs=[(big, True)], dtypes=("f32",),
                         variants=("full",))
    assert fs and all(f.checker == "vmem-budget" for f in fs)
    assert errors(fs), "must-fit config over budget must be an error"


def test_launch_estimates_report_all_kernels():
    est = vmem.block_launch_estimates(get_config("fno2d", reduced=True))
    assert set(est) == {"block_fwd", "gz_recompute", "dx_adjoint", "wgrad"}
    assert all(e.total_bytes > 0 for e in est.values())
    part = vmem.block_launch_estimates(get_config("fno2d", reduced=True),
                                       variant="partial")
    assert "core" in part and "block_fwd" not in part


def test_ends_launch_estimate_and_feasibility():
    # ISSUE 8: fuse_ends adds exactly one launch kind to the estimate set
    # (the ends-fused forward — backward re-stages, no new kernels). The
    # acca scratch [lift, bb, *spatial] dominates: reduced shapes fit,
    # the full-size 3D grid does not, and opting in surfaces that as a
    # vmem-budget error instead of a Mosaic failure mid-run.
    from repro.configs.fno import with_fuse_ends

    cfg = with_fuse_ends(get_config("fno2d", reduced=True))
    est = vmem.block_launch_estimates(cfg)
    assert "block_fwd_ends" in est
    e = est["block_fwd_ends"]
    assert 0 < e.total_bytes <= vmem.VMEM_BUDGET_BYTES
    assert e.scratch_bytes > est["block_fwd"].scratch_bytes  # + acca
    # without the flag the launch is absent (default sweeps unchanged)
    assert "block_fwd_ends" not in vmem.block_launch_estimates(
        get_config("fno2d", reduced=True))
    fs = vmem.check_vmem(configs=[with_fuse_ends(get_config("fno3d"))],
                         dtypes=("f32",), variants=("full",))
    assert any(f.target.endswith("block_fwd_ends") for f in fs), fs
    assert errors(fs)
