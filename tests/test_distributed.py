"""Multi-device distribution tests (subprocess with 8 virtual CPU devices):
sharded-vs-single equivalence, pipeline parallelism, gradient compression,
elastic restore, dry-run cell compilation, and the DP×TP fused-FNO path
(ISSUE 5: the shard_map dispatch in kernels.ops + the FNO leaf specs)."""
import pytest


def test_sharded_train_step_matches_single(subproc):
    subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.models import transformer as tf
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.train_step import make_train_step

    cfg = get_config("qwen2-1.5b", reduced=True)
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg, jnp.float32)
    opt = AdamW(lr=constant(1e-3))
    opt_state = opt.init(params)
    tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens,
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}

    step = make_train_step(cfg, opt)
    p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

    mesh = make_debug_mesh(4, 2)
    ctx = shd.make_context(cfg, mesh)
    pspec = shd.param_specs(cfg, mesh, params)
    ospec = {"m": pspec, "v": pspec, "step": jax.sharding.PartitionSpec()}
    bspec = shd.batch_specs(cfg, ctx, batch)
    sh = lambda t: shd.shardings_from_specs(t, mesh)
    def step_ctx(p, o, b):
        with shd.sharding_context(ctx):
            return step(p, o, b)
    j = jax.jit(step_ctx, in_shardings=(sh(pspec), sh(ospec), sh(bspec)))
    p2, o2, m2 = j(params, opt_state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    mx = max(jax.tree_util.tree_leaves(d))
    assert mx < 2e-4, mx
    print("sharded==single OK", mx)
    """)


def test_gpipe_matches_sequential(subproc):
    subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_compat_mesh
    from repro.distributed.pipeline import make_gpipe_fn

    S, M, mb, d = 4, 6, 2, 16
    mesh = make_compat_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, d, d)) / d**0.5
    x = jax.random.normal(key, (M, mb, d))

    def stage_fn(w, xin):  # per-stage computation
        return jnp.tanh(xin @ w[0])

    f = make_gpipe_fn(stage_fn, mesh=mesh, axis="stage", num_stages=S,
                      stage_param_spec=P("stage"), x_spec=P())
    out = f(ws, x)

    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("gpipe OK")
    """)


def test_compressed_psum_error_feedback(subproc):
    subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.distributed import compression as comp
    from repro.launch.mesh import make_compat_mesh

    n = 8
    mesh = make_compat_mesh((n,), ("dp",))
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (n, 64, 64))

    def one(gs, res):
        return comp.ef_psum(gs, res, "dp")
    f = shard_map(one, mesh=mesh, in_specs=(P("dp"), P("dp")),
                  out_specs=(P("dp"), P("dp")), check_rep=False)

    res = jnp.zeros_like(g)
    exact = jnp.sum(g, axis=0)
    summed, res = f(g, res)
    err1 = float(jnp.abs(summed[0] - exact).max() / jnp.abs(exact).max())
    assert err1 < 0.05, err1  # int8 quantization error bound

    # error feedback: accumulated compressed sums converge to accumulated
    # exact sums over repeated reductions of the same gradient
    acc_c = jnp.zeros_like(exact)
    res = jnp.zeros_like(g)
    T = 20
    for _ in range(T):
        s, res = f(g, res)
        acc_c = acc_c + s[0]
    err_T = float(jnp.abs(acc_c / T - exact).max() / jnp.abs(exact).max())
    assert err_T < err1 / 2, (err1, err_T)
    print("compression OK", err1, err_T)
    """)


def test_elastic_restore_across_meshes(subproc):
    subproc("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.checkpoint import Checkpointer
    from repro.models import transformer as tf

    cfg = get_config("qwen2-1.5b", reduced=True)
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg, jnp.float32)

    mesh_a = make_debug_mesh(4, 2)  # 8 chips ("before failure")
    sh_a = shd.shardings_from_specs(
        shd.param_specs(cfg, mesh_a, params), mesh_a)
    params_a = jax.device_put(params, sh_a)

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(10, params_a)
        # "lost half the fleet": restore onto a 2x2 mesh
        mesh_b = make_debug_mesh(2, 2)
        sh_b = shd.shardings_from_specs(
            shd.param_specs(cfg, mesh_b, params), mesh_b)
        restored = ck.restore(10, params, shardings=sh_b)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), params, restored)
        leaf = restored["layers"]["attn"]["wq"]["w"]
        assert leaf.sharding.mesh.shape["data"] == 2
    print("elastic restore OK")
    """)


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-1.5b", "train_4k"),
    ("mixtral-8x7b", "decode_32k"),
    ("mamba2-370m", "long_500k"),
    ("gemma3-27b", "prefill_32k"),
])
def test_reduced_cells_compile_multipod(subproc, arch, shape):
    subproc(f"""
    import jax
    from repro.launch import cells as cm
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(2, 2, 2)  # pod x data x model
    cell = cm.build_cell("{arch}", "{shape}", mesh, reduced=True)
    j = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
    co = j.lower(*cell.args).compile()
    from repro.roofline.compat import cost_analysis_dict
    ca = cost_analysis_dict(co)
    assert ca.get("flops", 0) > 0
    print("cell OK", "{arch}", "{shape}")
    """)


# ---------------------------------------------------------------------------
# Sharded FNO (ISSUE 5): the fused pallas block under DP and DP×TP meshes
# must match the single-device XLA oracle to the test_precision f32
# tolerance (2e-4); TP shards the hidden k-loop axis with the partial
# pre-activations psum-reduced inside the shard_map dispatch.
# ---------------------------------------------------------------------------
def test_fno_dp_tp_fused_block_matches_single(subproc):
    subproc("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.core import fno as fno_mod

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    x = jax.random.normal(key, (8, cfg.in_channels) + tuple(cfg.spatial))
    y_ref = fno_mod.apply_fno(params, cfg, x, path="xla")

    for dp, tp in ((8, 1), (4, 2), (2, 4)):
        mesh = make_debug_mesh(dp, tp)
        ctx = shd.make_context(cfg, mesh, kind="serve")
        # tp=1 folds model into the batch axes (pure DP); tp>1 shards the
        # hidden k-loop axis over "model"
        assert (ctx.model_axis == "model") == (tp > 1), (dp, tp, ctx)
        def fwd(p, xx):
            with shd.sharding_context(ctx):
                return fno_mod.apply_fno(p, cfg, xx, path="pallas")
        y = jax.jit(fwd)(params, x)
        err = float(jnp.abs(y - y_ref).max())
        assert err < 2e-4, (dp, tp, err)
        print(f"dp={dp} tp={tp} max_err={err:.2e}")
    print("fno dp/tp parity OK")
    """)


def test_fno_tp_bf16_matches_single_device(subproc):
    # The TP cast contract: partial pre-activations cross the psum at the
    # ACCUMULATOR dtype (f32), so the bf16 DP×TP block must match the
    # single-device bf16 pallas path to f32-parity tolerance — not merely
    # the bf16-vs-f32 tolerance.
    subproc("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.configs.fno import with_precision
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.core import fno as fno_mod

    cfg = dataclasses.replace(
        with_precision(get_config("fno2d", reduced=True), "bf16"),
        path="pallas", fuse_block=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    x = jax.random.normal(key, (8, cfg.in_channels) + tuple(cfg.spatial))
    y_single = fno_mod.apply_fno(params, cfg, x, path="pallas")
    assert y_single.dtype == jnp.bfloat16, y_single.dtype

    mesh = make_debug_mesh(2, 4)
    ctx = shd.make_context(cfg, mesh)
    assert ctx.model_axis == "model"
    def fwd(p, xx):
        with shd.sharding_context(ctx):
            return fno_mod.apply_fno(p, cfg, xx, path="pallas")
    y = jax.jit(fwd)(params, x)
    assert y.dtype == jnp.bfloat16, y.dtype
    err = float(jnp.abs(y.astype(jnp.float32)
                        - y_single.astype(jnp.float32)).max())
    scale = float(jnp.abs(y_single.astype(jnp.float32)).max())
    assert err < 2e-2 * max(scale, 1.0), (err, scale)
    print("fno bf16 tp parity OK", err)
    """)


def test_fno_dp_tp_grads_match_single(subproc):
    subproc("""
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.core import fno as fno_mod

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    batch = {
        "x": jax.random.normal(key, (8, cfg.in_channels)
                               + tuple(cfg.spatial)),
        "y": jax.random.normal(jax.random.fold_in(key, 1),
                               (8, cfg.out_channels) + tuple(cfg.spatial)),
    }
    g_ref = jax.grad(
        lambda p: fno_mod.fno_loss(p, cfg, batch, path="xla"))(params)

    mesh = make_debug_mesh(4, 2)
    ctx = shd.make_context(cfg, mesh)
    def loss(p):
        with shd.sharding_context(ctx):
            return fno_mod.fno_loss(p, cfg, batch, path="pallas")
    g = jax.jit(jax.grad(loss))(params)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), g, g_ref)
    mx = max(jax.tree_util.tree_leaves(d))
    assert mx < 1e-4, mx
    print("fno dp x tp grads OK", mx)
    """)


def test_fno_train_step_has_no_explicit_psum():
    # The ef_psum scope contract (distributed/compression.py): the FNO
    # train step hand-writes NO gradient collective — outside a sharding
    # context the whole step traces zero collectives. Under a DP jit the
    # all-reduce is GSPMD's (derived from the batch-axis sharding; the
    # only trace-level psums a DP context adds are shard_map's OWN
    # weight-grad transposes inside the fused-block dispatch). Wiring
    # tree_ef_psum into the step would both break this budget and
    # double-reduce.
    import dataclasses

    import jax
    import jax.numpy as jnp

    from repro.analysis import jaxpr_lint as jl
    from repro.configs import get_config
    from repro.core import fno as fno_mod
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.train_step import make_train_step

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    opt = AdamW(lr=constant(1e-3))
    state = opt.init(params)
    batch = {"x": jnp.zeros((2, cfg.in_channels) + tuple(cfg.spatial)),
             "y": jnp.zeros((2, cfg.out_channels) + tuple(cfg.spatial))}
    step = make_train_step(cfg, opt, fno_path="pallas")
    counts = jl.collective_counts(step, params, state, batch)
    assert counts == {}, counts


def test_fno_collective_bytes_model():
    """The roofline collective-traffic model (ISSUE 8) — pure math, no
    devices: scattered interior layers move exactly HALF the psum
    layout's wire bytes, the final layer always all-reduces, and TP that
    folds away (tp=1 or hidden % tp != 0) costs zero."""
    import math

    from repro.configs import get_config
    from repro.configs.fno import with_precision
    from repro.roofline.analysis import fno_collective_bytes

    cfg = get_config("fno2d", reduced=True)
    sc = fno_collective_bytes(cfg, 4, 2, scattered=True, batch=8)
    ps = fno_collective_bytes(cfg, 4, 2, scattered=False, batch=8)
    assert sc["interior_per_layer"] == 0.5 * ps["interior_per_layer"]
    assert sc["final"] == ps["final"]  # the projection needs full hidden
    L = cfg.num_layers
    assert ps["total"] == L * ps["interior_per_layer"]
    assert sc["total"] == (L - 1) * sc["interior_per_layer"] + sc["final"]
    # exact ring wire bytes: T = (8/4)·hidden·∏spatial·4 B, tp=2
    t = 2 * cfg.hidden * math.prod(cfg.spatial) * 4
    assert ps["interior_per_layer"] == 2 * (2 - 1) / 2 * t
    # bf16 activations halve the collective traffic
    sc16 = fno_collective_bytes(with_precision(cfg, "bf16"), 4, 2, batch=8)
    assert sc16["total"] == 0.5 * sc["total"]
    # degradation mirrors make_context
    assert fno_collective_bytes(cfg, 8, 1)["total"] == 0.0
    assert fno_collective_bytes(cfg, 2, 3)["total"] == 0.0  # 16 % 3 != 0


def test_fno_tp_scatter_layout_parity_and_budget(subproc):
    # ISSUE 8 tentpole: the scattered TP layout (interior layers complete
    # their sharded k-loop with a psum_scatter emitting the NEXT layer's
    # hidden shard; only the final layer psums) — fwd + grad parity vs the
    # single-device XLA oracle, and the exact collective budget.
    subproc("""
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.core import fno as fno_mod
    from repro.analysis import jaxpr_lint as jl

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True)
    assert cfg.tp_layout == "scatter"  # scattered is the default layout
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    x = jax.random.normal(key, (8, cfg.in_channels) + tuple(cfg.spatial))
    y_ref = fno_mod.apply_fno(params, cfg, x, path="xla")
    g_ref = jax.grad(lambda p: jnp.sum(
        fno_mod.apply_fno(p, cfg, x, path="xla") ** 2))(params)
    denom = max(float(jnp.abs(l).max())
                for l in jax.tree_util.tree_leaves(g_ref))

    for dp, tp in ((4, 2), (2, 4)):
        mesh = make_debug_mesh(dp, tp)
        ctx = shd.make_context(cfg, mesh, kind="serve")
        assert ctx.model_axis == "model"
        # fresh closures per mesh: jax.make_jaxpr caches on function
        # identity + avals, and the thread-local sharding context is
        # invisible to that cache — a reused closure would replay the
        # previous mesh's trace.
        def fwd(p, xx, _ctx=ctx):
            with shd.sharding_context(_ctx):
                return fno_mod.apply_fno(p, cfg, xx, path="pallas")
        y = jax.jit(fwd)(params, x)
        err = float(jnp.abs(y - y_ref).max())
        assert err < 2e-4, (dp, tp, err)
        g = jax.jit(jax.grad(
            lambda p, xx, _f=fwd: jnp.sum(_f(p, xx) ** 2)))(params, x)
        gerr = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(g),
            jax.tree_util.tree_leaves(g_ref))) / denom
        assert gerr < 2e-4, (dp, tp, gerr)
        counts = jl.collective_counts(fwd, params, x)
        rs = counts.get("reduce_scatter", 0) + counts.get("psum_scatter", 0)
        assert rs == cfg.num_layers - 1, counts  # one per INTERIOR layer
        assert counts.get("psum", 0) == 1, counts  # final layer only
        assert jl.pallas_count(fwd, params, x) == cfg.num_layers
        print(f"dp{dp}xtp{tp}: fwd={err:.2e} relgrad={gerr:.2e} "
              f"coll={counts}")
    print("scattered TP layout parity + budget OK")
    """)


def test_fno_tp_layouts_agree_and_overlap_ring(subproc):
    # The three TP collective plans are the same math: psum layout,
    # scattered layout, and the scattered layout with the ppermute ring
    # (tp_overlap) all match bitwise-tight; the ring traces tp-1
    # ppermutes per interior layer in place of the one-shot
    # reduce-scatter. Grads flow through the ring natively (ppermute
    # transposes to ppermute).
    subproc("""
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.core import fno as fno_mod
    from repro.analysis import jaxpr_lint as jl

    cfg0 = dataclasses.replace(get_config("fno2d", reduced=True),
                               path="pallas", fuse_block=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg0)
    x = jax.random.normal(key, (8, cfg0.in_channels) + tuple(cfg0.spatial))
    dp, tp = 2, 4
    mesh = make_debug_mesh(dp, tp)

    outs, grads, colls = {}, {}, {}
    for layout, overlap in (("psum", False), ("scatter", False),
                            ("scatter", True)):
        cfg = dataclasses.replace(cfg0, tp_layout=layout,
                                  tp_overlap=overlap)
        ctx = shd.make_context(cfg, mesh, kind="serve")
        def fwd(p, xx, _cfg=cfg, _ctx=ctx):  # fresh closure per variant
            with shd.sharding_context(_ctx):
                return fno_mod.apply_fno(p, _cfg, xx, path="pallas")
        name = layout + ("+ring" if overlap else "")
        outs[name] = jax.jit(fwd)(params, x)
        grads[name] = jax.jit(jax.grad(
            lambda p, xx, _f=fwd: jnp.sum(_f(p, xx) ** 2)))(params, x)
        colls[name] = jl.collective_counts(fwd, params, x)

    for name in ("scatter", "scatter+ring"):
        err = float(jnp.abs(outs[name] - outs["psum"]).max())
        assert err < 1e-5, (name, err)
        gerr = max(float(jnp.abs(a - b).max()) for a, b in zip(
            jax.tree_util.tree_leaves(grads[name]),
            jax.tree_util.tree_leaves(grads["psum"])))
        assert gerr < 1e-4, (name, gerr)
    L = cfg0.num_layers
    assert colls["psum"] == {"psum": L}, colls["psum"]
    assert colls["scatter"].get("ppermute", 0) == 0, colls["scatter"]
    ring = colls["scatter+ring"]
    assert ring.get("ppermute", 0) == (tp - 1) * (L - 1), ring
    assert ring.get("reduce_scatter", 0) == 0 and \
        ring.get("psum_scatter", 0) == 0, ring
    assert ring.get("psum", 0) == 1, ring
    print("layout equivalence + overlap ring OK", colls)
    """)


def test_fno_fused_ends_sharded_dispatch(subproc):
    # cfg.fuse_ends under shard_map: pure DP keeps the ends fused (zero
    # collectives, num_layers pallas_calls, parity); with TP on, the guard
    # in core.fno falls back to staged ends while the scattered interior
    # collectives stay intact.
    subproc("""
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.core import fno as fno_mod
    from repro.analysis import jaxpr_lint as jl

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              path="pallas", fuse_block=True,
                              fuse_ends=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    x = jax.random.normal(key, (8, cfg.in_channels) + tuple(cfg.spatial))
    y_ref = fno_mod.apply_fno(params, cfg, x, path="xla")

    # pure DP (8x1) and DP with the model axis folded (4x2, strategy=dp):
    # ends stay fused.
    for mesh, strategy in ((make_debug_mesh(8, 1), None),
                           (make_debug_mesh(4, 2), "dp")):
        ctx = shd.make_context(cfg, mesh, fno_strategy=strategy,
                               kind="serve")
        assert ctx.model_axis is None
        def fwd(p, xx, _ctx=ctx):  # fresh closure per context
            with shd.sharding_context(_ctx):
                return fno_mod.apply_fno(p, cfg, xx, path="pallas")
        y = jax.jit(fwd)(params, x)
        err = float(jnp.abs(y - y_ref).max())
        assert err < 2e-4, err
        assert jl.pallas_count(fwd, params, x) == cfg.num_layers
        assert jl.collective_counts(fwd, params, x) == {}

    # TP on: fuse_ends is ignored (the projection needs the full
    # post-psum hidden vector), the scattered budget is unchanged.
    ctx = shd.make_context(cfg, make_debug_mesh(4, 2), kind="serve")
    assert ctx.model_axis == "model"
    def fwd_tp(p, xx, _ctx=ctx):
        with shd.sharding_context(_ctx):
            return fno_mod.apply_fno(p, cfg, xx, path="pallas")
    y = jax.jit(fwd_tp)(params, x)
    assert float(jnp.abs(y - y_ref).max()) < 2e-4
    counts = jl.collective_counts(fwd_tp, params, x)
    rs = counts.get("reduce_scatter", 0) + counts.get("psum_scatter", 0)
    assert rs == cfg.num_layers - 1 and counts.get("psum", 0) == 1, counts
    print("fused ends sharded dispatch OK")
    """)


def test_fno_leaf_specs_and_guard(subproc):
    subproc("""
    import jax, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.core import fno as fno_mod

    cfg = get_config("fno2d", reduced=True)  # hidden=16
    params = jax.eval_shape(
        lambda: fno_mod.init_fno(jax.random.PRNGKey(0), cfg))

    # TP divides hidden (16 % 2 == 0): spectral shards the HIDDEN (k-loop)
    # axis, bypass shards its contraction dim, biases replicate.
    mesh = make_debug_mesh(4, 2)
    specs = shd.param_specs(cfg, mesh, params)
    blk = specs["blocks"][0]
    assert blk["spectral"]["wr"] == P(None, "model"), blk["spectral"]["wr"]
    assert blk["bypass"]["w"] == P("model", None), blk["bypass"]["w"]
    assert blk["bypass"]["b"] == P(None), blk["bypass"]["b"]
    assert specs["lift2"]["w"] == P("model", None)
    assert specs["proj1"]["w"] == P("model", None)

    # guard_spec regression: a model axis that does NOT divide hidden must
    # degrade the FNO leaf specs to replication, not error (mesh 2x3 on 8
    # forced devices: 16 % 3 != 0).
    mesh3 = shd.Mesh(np.array(jax.devices()[:6]).reshape(2, 3),
                     ("data", "model"))
    specs3 = shd.param_specs(cfg, mesh3, params)
    for leaf in jax.tree_util.tree_leaves(
            specs3, is_leaf=lambda s: isinstance(s, P)):
        assert all(e is None for e in tuple(leaf)), leaf
    # ...and make_context folds the unusable model axis into the batch.
    ctx3 = shd.make_context(cfg, mesh3)
    assert ctx3.model_axis is None and "model" in ctx3.batch_axes

    # fno_tp=False (pure DP) replicates even when hidden divides.
    specs_dp = shd.param_specs(cfg, mesh, params, fno_tp=False)
    for leaf in jax.tree_util.tree_leaves(
            specs_dp, is_leaf=lambda s: isinstance(s, P)):
        assert all(e is None for e in tuple(leaf)), leaf

    # spec trees always match the params structure exactly.
    assert (jax.tree_util.tree_structure(specs,
                is_leaf=lambda s: isinstance(s, P)).num_leaves
            == jax.tree_util.tree_structure(params).num_leaves)
    print("fno leaf specs + guard OK")
    """)


@pytest.mark.parametrize("shape,kw,want_tp", [
    # training defaults to pure DP (batch >> hidden: model axis folds into
    # the batch, weights replicate); TP is opt-in via fno_strategy
    ("train_4k", "", False),
    ("train_4k", ", fno_strategy='auto'", True),
    # the serving cell keeps the auto DP x TP grid
    ("prefill_32k", "", True),
])
def test_fno_cells_compile_dp_tp(subproc, shape, kw, want_tp):
    subproc(f"""
    import jax
    from repro.launch import cells as cm
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(4, 2)
    cell = cm.build_cell("fno2d", "{shape}", mesh, reduced=True{kw})
    # the production FNO cells run the fused pallas path by default
    assert (cell.ctx.model_axis == "model") == {want_tp}, cell.ctx
    j = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
    co = j.lower(*cell.args).compile()
    from repro.roofline.compat import cost_analysis_dict
    ca = cost_analysis_dict(co)
    assert ca.get("flops", 0) > 0
    print("fno cell OK", "{shape}")
    """)
