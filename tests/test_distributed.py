"""Multi-device distribution tests (subprocess with 8 virtual CPU devices):
sharded-vs-single equivalence, pipeline parallelism, gradient compression,
elastic restore, dry-run cell compilation."""
import pytest


def test_sharded_train_step_matches_single(subproc):
    subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.models import transformer as tf
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.train_step import make_train_step

    cfg = get_config("qwen2-1.5b", reduced=True)
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg, jnp.float32)
    opt = AdamW(lr=constant(1e-3))
    opt_state = opt.init(params)
    tokens = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": tokens,
             "labels": jax.random.randint(key, (4, 32), 0, cfg.vocab_size)}

    step = make_train_step(cfg, opt)
    p1, o1, m1 = jax.jit(step)(params, opt_state, batch)

    mesh = make_debug_mesh(4, 2)
    ctx = shd.make_context(cfg, mesh)
    pspec = shd.param_specs(cfg, mesh, params)
    ospec = {"m": pspec, "v": pspec, "step": jax.sharding.PartitionSpec()}
    bspec = shd.batch_specs(cfg, ctx, batch)
    sh = lambda t: shd.shardings_from_specs(t, mesh)
    def step_ctx(p, o, b):
        with shd.sharding_context(ctx):
            return step(p, o, b)
    j = jax.jit(step_ctx, in_shardings=(sh(pspec), sh(ospec), sh(bspec)))
    p2, o2, m2 = j(params, opt_state, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    d = jax.tree_util.tree_map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)
    mx = max(jax.tree_util.tree_leaves(d))
    assert mx < 2e-4, mx
    print("sharded==single OK", mx)
    """)


def test_gpipe_matches_sequential(subproc):
    subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import make_compat_mesh
    from repro.distributed.pipeline import make_gpipe_fn

    S, M, mb, d = 4, 6, 2, 16
    mesh = make_compat_mesh((S,), ("stage",))
    key = jax.random.PRNGKey(0)
    ws = jax.random.normal(key, (S, d, d)) / d**0.5
    x = jax.random.normal(key, (M, mb, d))

    def stage_fn(w, xin):  # per-stage computation
        return jnp.tanh(xin @ w[0])

    f = make_gpipe_fn(stage_fn, mesh=mesh, axis="stage", num_stages=S,
                      stage_param_spec=P("stage"), x_spec=P())
    out = f(ws, x)

    # sequential reference
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ ws[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    print("gpipe OK")
    """)


def test_compressed_psum_error_feedback(subproc):
    subproc("""
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map
    from repro.distributed import compression as comp
    from repro.launch.mesh import make_compat_mesh

    n = 8
    mesh = make_compat_mesh((n,), ("dp",))
    key = jax.random.PRNGKey(0)
    g = jax.random.normal(key, (n, 64, 64))

    def one(gs, res):
        return comp.ef_psum(gs, res, "dp")
    f = shard_map(one, mesh=mesh, in_specs=(P("dp"), P("dp")),
                  out_specs=(P("dp"), P("dp")), check_rep=False)

    res = jnp.zeros_like(g)
    exact = jnp.sum(g, axis=0)
    summed, res = f(g, res)
    err1 = float(jnp.abs(summed[0] - exact).max() / jnp.abs(exact).max())
    assert err1 < 0.05, err1  # int8 quantization error bound

    # error feedback: accumulated compressed sums converge to accumulated
    # exact sums over repeated reductions of the same gradient
    acc_c = jnp.zeros_like(exact)
    res = jnp.zeros_like(g)
    T = 20
    for _ in range(T):
        s, res = f(g, res)
        acc_c = acc_c + s[0]
    err_T = float(jnp.abs(acc_c / T - exact).max() / jnp.abs(exact).max())
    assert err_T < err1 / 2, (err1, err_T)
    print("compression OK", err1, err_T)
    """)


def test_elastic_restore_across_meshes(subproc):
    subproc("""
    import tempfile, jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_config
    from repro.launch.mesh import make_debug_mesh
    from repro.distributed import sharding as shd
    from repro.checkpoint import Checkpointer
    from repro.models import transformer as tf

    cfg = get_config("qwen2-1.5b", reduced=True)
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg, jnp.float32)

    mesh_a = make_debug_mesh(4, 2)  # 8 chips ("before failure")
    sh_a = shd.shardings_from_specs(
        shd.param_specs(cfg, mesh_a, params), mesh_a)
    params_a = jax.device_put(params, sh_a)

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d)
        ck.save(10, params_a)
        # "lost half the fleet": restore onto a 2x2 mesh
        mesh_b = make_debug_mesh(2, 2)
        sh_b = shd.shardings_from_specs(
            shd.param_specs(cfg, mesh_b, params), mesh_b)
        restored = ck.restore(10, params, shardings=sh_b)
        jax.tree_util.tree_map(
            lambda a, b: np.testing.assert_array_equal(
                np.asarray(a), np.asarray(b)), params, restored)
        leaf = restored["layers"]["attn"]["wq"]["w"]
        assert leaf.sharding.mesh.shape["data"] == 2
    print("elastic restore OK")
    """)


@pytest.mark.parametrize("arch,shape", [
    ("qwen2-1.5b", "train_4k"),
    ("mixtral-8x7b", "decode_32k"),
    ("mamba2-370m", "long_500k"),
    ("gemma3-27b", "prefill_32k"),
])
def test_reduced_cells_compile_multipod(subproc, arch, shape):
    subproc(f"""
    import jax
    from repro.launch import cells as cm
    from repro.launch.mesh import make_debug_mesh
    mesh = make_debug_mesh(2, 2, 2)  # pod x data x model
    cell = cm.build_cell("{arch}", "{shape}", mesh, reduced=True)
    j = jax.jit(cell.step_fn, in_shardings=cell.in_shardings)
    co = j.lower(*cell.args).compile()
    ca = co.cost_analysis()
    ca = ca[0] if isinstance(ca, list) else ca  # list-of-dicts on jax 0.4.x
    assert ca.get("flops", 0) > 0
    print("cell OK", "{arch}", "{shape}")
    """)
