"""Differentiability of the fused Pallas spectral layers.

jax.grad through path="pallas" must match path="xla" (which XLA
differentiates automatically) to 1e-4 in f32 — for dx, dwr, and dwi, in
1D/2D/3D, shared and per-mode weights, full and partial fusion. Plus a
train_step smoke test with fno_path="pallas" proving the trainer never
falls back to XLA.

A nonlinear readout (sin) makes the incoming cotangent non-trivial so the
adjoint pipeline is exercised with a dense, structured gy.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops

TOL = dict(rtol=1e-4, atol=1e-4)


def _mk(rng, *s, scale=1.0):
    return jnp.asarray(scale * rng.normal(size=s), jnp.float32)


def _grads(layer_fn, x, wr, wi):
    loss = lambda x, wr, wi: jnp.sum(jnp.sin(layer_fn(x, wr, wi)))
    return jax.grad(loss, argnums=(0, 1, 2))(x, wr, wi)


def _assert_grads_match(make_fn, x, wr, wi):
    gp = _grads(make_fn("pallas"), x, wr, wi)
    gx = _grads(make_fn("xla"), x, wr, wi)
    for name, a, b in zip(("dx", "dwr", "dwi"), gp, gx):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), err_msg=name,
                                   **TOL)


CASES_1D = [
    # B, H, O, N, K
    (2, 8, 6, 64, 17),
    (3, 16, 16, 128, 33),
]


@pytest.mark.parametrize("b,h,o,n,k", CASES_1D)
@pytest.mark.parametrize("weight_mode", ["shared", "per_mode"])
def test_grad_fused_fno1d(b, h, o, n, k, weight_mode):
    rng = np.random.default_rng(b * 13 + k)
    x = _mk(rng, b, h, n)
    wshape = (o, h) if weight_mode == "shared" else (o, h, k)
    wr = _mk(rng, *wshape, scale=1.0 / h)
    wi = _mk(rng, *wshape, scale=1.0 / h)
    mk = lambda p: lambda x, wr, wi: ops.spectral_layer_1d(
        x, wr, wi, k, path=p)
    _assert_grads_match(mk, x, wr, wi)


CASES_2D = [
    # B, H, O, X, Y, KX, KY
    (2, 8, 6, 16, 32, 5, 9),
    (1, 12, 12, 32, 32, 8, 8),
]


@pytest.mark.parametrize("b,h,o,x_,y_,kx,ky", CASES_2D)
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_grad_fused_fno2d_shared(b, h, o, x_, y_, kx, ky, variant):
    rng = np.random.default_rng(x_ * 3 + ky)
    x = _mk(rng, b, h, x_, y_)
    wr = _mk(rng, o, h, scale=1.0 / h)
    wi = _mk(rng, o, h, scale=1.0 / h)
    mk = lambda p: lambda x, wr, wi: ops.spectral_layer_2d(
        x, wr, wi, (kx, ky), path=p, variant=variant if p == "pallas"
        else "full")
    _assert_grads_match(mk, x, wr, wi)


@pytest.mark.parametrize("b,h,o,x_,y_,kx,ky", CASES_2D[:1])
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_grad_fused_fno2d_permode(b, h, o, x_, y_, kx, ky, variant):
    rng = np.random.default_rng(7)
    x = _mk(rng, b, h, x_, y_)
    wr = _mk(rng, o, h, kx, ky, scale=1.0 / h)
    wi = _mk(rng, o, h, kx, ky, scale=1.0 / h)
    mk = lambda p: lambda x, wr, wi: ops.spectral_layer_2d(
        x, wr, wi, (kx, ky), path=p, variant=variant if p == "pallas"
        else "full")
    _assert_grads_match(mk, x, wr, wi)


CASES_3D = [
    # B, H, O, X, Y, Z, KX, KY, KZ
    (1, 4, 4, 8, 8, 16, 3, 3, 5),
]


@pytest.mark.parametrize("b,h,o,x_,y_,z_,kx,ky,kz", CASES_3D)
@pytest.mark.parametrize("weight_mode", ["shared", "per_mode"])
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_grad_fused_fno3d(b, h, o, x_, y_, z_, kx, ky, kz, weight_mode,
                          variant):
    rng = np.random.default_rng(z_ + kz)
    x = _mk(rng, b, h, x_, y_, z_)
    wshape = ((o, h) if weight_mode == "shared"
              else (o, h, kx, ky, kz))
    wr = _mk(rng, *wshape, scale=1.0 / h)
    wi = _mk(rng, *wshape, scale=1.0 / h)
    mk = lambda p: lambda x, wr, wi: ops.spectral_layer_3d(
        x, wr, wi, (kx, ky, kz), path=p, variant=variant if p == "pallas"
        else "full")
    _assert_grads_match(mk, x, wr, wi)


def test_grad_linearity_in_cotangent():
    """The bwd pass is linear: vjp(a·g1 + g2) = a·vjp(g1) + vjp(g2)."""
    rng = np.random.default_rng(3)
    x = _mk(rng, 2, 8, 64)
    wr, wi = _mk(rng, 8, 8, scale=1 / 8), _mk(rng, 8, 8, scale=1 / 8)
    f = lambda x: ops.spectral_layer_1d(x, wr, wi, 17, path="pallas")
    y, vjp = jax.vjp(f, x)
    g1 = _mk(rng, *y.shape)
    g2 = _mk(rng, *y.shape)
    lhs = vjp(2.5 * g1 + g2)[0]
    rhs = 2.5 * vjp(g1)[0] + vjp(g2)[0]
    np.testing.assert_allclose(np.asarray(lhs), np.asarray(rhs), rtol=1e-4,
                               atol=1e-4)


_BLOCK_CASES = {
    1: ((64,), (17,)),
    2: ((16, 32), (5, 9)),
    3: ((8, 8, 16), (3, 3, 5)),
}


def _block_grads(fn, x, wr, wi, wb, bias):
    loss = lambda *a: jnp.sum(jnp.sin(fn(*a)))
    return jax.grad(loss, argnums=(0, 1, 2, 3, 4))(x, wr, wi, wb, bias)


def _assert_rel(name, a, b, tol=1e-4):
    scale = max(float(jnp.abs(jnp.asarray(b)).max()), 1.0)
    np.testing.assert_allclose(np.asarray(a) / scale, np.asarray(b) / scale,
                               rtol=tol, atol=tol, err_msg=name)


@pytest.mark.parametrize("rank", [1, 2, 3])
@pytest.mark.parametrize("weight_mode", ["shared", "per_mode"])
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_grad_fused_block(rank, weight_mode, variant):
    """All four fused-block cotangents — dx, dW (re+im), dW_b, dbias —
    match jax.grad through the XLA oracle, every rank, both weight
    layouts, both fusion variants (the backward is the fully fused
    pipeline either way: gz recompute + dx adjoint + extended wgrad)."""
    if rank == 1 and variant == "partial":
        pytest.skip("rank 1 has no partial variant")
    spatial, modes = _BLOCK_CASES[rank]
    rng = np.random.default_rng(rank * 17 + len(spatial))
    x = _mk(rng, 2, 8, *spatial)
    wshape = (6, 8) if weight_mode == "shared" else (6, 8) + modes
    wr = _mk(rng, *wshape, scale=1.0 / 8)
    wi = _mk(rng, *wshape, scale=1.0 / 8)
    wb = _mk(rng, 6, 8, scale=1.0 / 8)
    bias = _mk(rng, 6, scale=0.3)
    mk = lambda p: lambda *a: ops.fno_block_nd(
        *a, modes, path=p, variant=variant if p == "pallas" else "full")
    gp = _block_grads(mk("pallas"), x, wr, wi, wb, bias)
    gx = _block_grads(mk("xla"), x, wr, wi, wb, bias)
    for name, a, b in zip(("dx", "dwr", "dwi", "dwb", "dbias"), gp, gx):
        _assert_rel(name, a, b)


def test_train_step_pallas_path():
    """One AdamW train step end-to-end on the fused path: loss finite,
    params move, and the metrics match the XLA path to tolerance."""
    from repro.configs import get_config
    from repro.core import fno as fno_mod
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.train_step import make_train_step

    cfg = get_config("fno2d", reduced=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    opt = AdamW(lr=constant(1e-3))
    rng = np.random.default_rng(0)
    batch = {"x": _mk(rng, 2, cfg.in_channels, *cfg.spatial),
             "y": _mk(rng, 2, cfg.out_channels, *cfg.spatial)}

    outs = {}
    for path in ("xla", "pallas"):
        step = jax.jit(make_train_step(cfg, opt, fno_path=path))
        p, s, m = step(params, opt.init(params), batch)
        assert bool(jnp.isfinite(m["loss"]))
        assert float(m["grad_norm"]) > 0.0
        moved = jax.tree_util.tree_map(
            lambda a, b: float(jnp.abs(a - b).max()), params, p)
        assert max(jax.tree_util.tree_leaves(moved)) > 0.0
        outs[path] = m
    np.testing.assert_allclose(float(outs["pallas"]["loss"]),
                               float(outs["xla"]["loss"]), rtol=1e-4)
    np.testing.assert_allclose(float(outs["pallas"]["grad_norm"]),
                               float(outs["xla"]["grad_norm"]), rtol=1e-3)
