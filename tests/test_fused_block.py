"""The fused FNO BLOCK (PR 4): gelu(spectral(x) + 1×1 bypass + bias) as
ONE pallas_call on the full-fusion path, end-to-end differentiable.

Covers: forward parity vs the staged XLA oracle (ranks 1–3, both weight
layouts, full + partial variants, f32 ≤ 2e-4 relative), the
single-pallas_call trace guard (block forward == exactly 1; jax.grad ==
exactly 4 — fwd + gz recompute + dx adjoint + extended wgrad — so all
four cotangents stay on fused kernels), model-level integration through
``apply_fno`` with cfg.fuse_block, and a train-step convergence smoke.
bf16-policy parity lives in tests/test_precision.py; per-cotangent grad
value checks in tests/test_kernels_grad.py.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops
from repro.roofline.hlo_counter import count_pallas_calls

_CASES = {
    1: ((64,), (17,)),
    2: ((16, 32), (5, 9)),
    3: ((8, 8, 16), (3, 3, 5)),
}


def _mk(rng, *s, scale=1.0):
    return jnp.asarray(scale * rng.normal(size=s), jnp.float32)


def _block_args(rng, rank, weight_mode, b=2, h=8, o=6):
    spatial, modes = _CASES[rank]
    x = _mk(rng, b, h, *spatial)
    wshape = (o, h) if weight_mode == "shared" else (o, h) + modes
    wr = _mk(rng, *wshape, scale=1.0 / h)
    wi = _mk(rng, *wshape, scale=1.0 / h)
    wb = _mk(rng, o, h, scale=1.0 / h)
    bias = _mk(rng, o, scale=0.3)
    return (x, wr, wi, wb, bias), modes


def _allclose_rel(a, b, tol, **kw):
    """Tolerance scaled to the reference magnitude (sums over B·∏s terms
    make the raw values O(100+); the contract is relative)."""
    scale = max(float(jnp.abs(b).max()), 1.0)
    np.testing.assert_allclose(np.asarray(a, np.float32) / scale,
                               np.asarray(b, np.float32) / scale,
                               rtol=tol, atol=tol, **kw)


@pytest.mark.parametrize("rank", [1, 2, 3])
@pytest.mark.parametrize("weight_mode", ["shared", "per_mode"])
@pytest.mark.parametrize("variant", ["full", "partial"])
def test_block_forward_parity_f32(rank, weight_mode, variant):
    if rank == 1 and variant == "partial":
        pytest.skip("rank 1 has no partial variant")
    rng = np.random.default_rng(rank * 5 + (weight_mode == "per_mode"))
    args, modes = _block_args(rng, rank, weight_mode)
    y = ops.fno_block_nd(*args, modes, path="pallas", variant=variant)
    for oracle in ("xla", "ref"):
        yref = ops.fno_block_nd(*args, modes, path=oracle)
        _allclose_rel(y, yref, 2e-4, err_msg=oracle)


def test_block_forward_is_one_pallas_call():
    """Acceptance guard: the full-fusion block forward lowers to exactly
    ONE pallas_call — spectral, bypass GEMM, bias, and GELU all inside."""
    rng = np.random.default_rng(0)
    for rank in (1, 2, 3):
        args, modes = _block_args(rng, rank, "shared")
        fn = lambda x: ops.fno_block_nd(x, *args[1:], modes, path="pallas",
                                        variant="full")
        assert count_pallas_calls(fn, args[0]) == 1, rank


def test_block_grad_stays_on_fused_kernels():
    """jax.grad of the fused block traces exactly 4 pallas_calls — the
    forward, the gz recompute (gelu_vjp epilogue), the dx adjoint, and
    the ONE extended wgrad emitting dW, dW_b, dbias — with no staged-XLA
    fallback for any of the four cotangents."""
    rng = np.random.default_rng(1)
    args, modes = _block_args(rng, 2, "shared")

    def loss(*a):
        return jnp.sum(jnp.sin(ops.fno_block_nd(*a, modes, path="pallas",
                                                variant="full")))

    g = lambda *a: jax.grad(loss, argnums=(0, 1, 2, 3, 4))(*a)
    assert count_pallas_calls(g, *args) == 4


def test_apply_fno_fused_block_model_parity():
    """cfg.fuse_block threads through apply_fno: one pallas_call per
    layer, output matches the unfused pallas path and the XLA oracle."""
    from repro.core import fno as fno_mod

    cfg0 = get_config("fno2d", reduced=True)
    cfg = dataclasses.replace(cfg0, fuse_block=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    x = _mk(rng, 2, cfg.in_channels, *cfg.spatial)
    y_fused = fno_mod.apply_fno(params, cfg, x, path="pallas")
    y_plain = fno_mod.apply_fno(params, cfg0, x, path="pallas")
    y_xla = fno_mod.apply_fno(params, cfg0, x, path="xla")
    _allclose_rel(y_fused, y_plain, 2e-4)
    _allclose_rel(y_fused, y_xla, 2e-4)
    # the staged paths ignore fuse_block (they stay the parity oracle)
    np.testing.assert_array_equal(
        np.asarray(fno_mod.apply_fno(params, cfg, x, path="xla")),
        np.asarray(y_xla))
    fn = lambda xx: fno_mod.apply_fno(params, cfg, xx, path="pallas")
    assert count_pallas_calls(fn, x) == cfg.num_layers


def test_block_3d_rank_generic():
    """The block epilogue is rank-generic: 3D fused block matches the
    oracle (the engine path the fno3d config exercises)."""
    from repro.core import fno as fno_mod

    cfg = dataclasses.replace(get_config("fno3d", reduced=True),
                              fuse_block=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(3)
    x = _mk(rng, 2, cfg.in_channels, *cfg.spatial)
    y = fno_mod.apply_fno(params, cfg, x, path="pallas")
    y_xla = fno_mod.apply_fno(params, cfg, x, path="xla")
    _allclose_rel(y, y_xla, 2e-4)


@pytest.mark.parametrize("arch", ["fno1d", "fno2d", "fno3d"])
def test_apply_fno_fused_ends_parity(arch):
    """cfg.fuse_ends folds the lifting MLP into the FIRST fused block
    kernel and the projection MLP into the LAST one (ISSUE 8): output and
    jax.grad match the staged XLA oracle, and the forward still traces
    exactly num_layers pallas_calls — the end MLPs add ZERO launches."""
    from repro.core import fno as fno_mod

    cfg0 = get_config(arch, reduced=True)
    cfg = dataclasses.replace(cfg0, fuse_block=True, fuse_ends=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(4)
    x = _mk(rng, 2, cfg.in_channels, *cfg.spatial)

    y = fno_mod.apply_fno(params, cfg, x, path="pallas")
    y_xla = fno_mod.apply_fno(params, cfg0, x, path="xla")
    _allclose_rel(y, y_xla, 2e-4)

    loss = lambda p, path, c: jnp.sum(
        fno_mod.apply_fno(p, c, x, path=path) ** 2)
    g = jax.grad(loss)(params, "pallas", cfg)
    g_ref = jax.grad(loss)(params, "xla", cfg0)
    for a, b in zip(jax.tree_util.tree_leaves(g),
                    jax.tree_util.tree_leaves(g_ref)):
        _allclose_rel(a, b, 2e-4)

    fn = lambda xx: fno_mod.apply_fno(params, cfg, xx, path="pallas")
    assert count_pallas_calls(fn, x) == cfg.num_layers


def test_fused_ends_one_layer_single_call():
    """The 1-layer degenerate case: lift prologue AND projection epilogue
    ride the SAME kernel — the whole model is ONE pallas_call."""
    from repro.core import fno as fno_mod

    cfg = dataclasses.replace(get_config("fno2d", reduced=True),
                              fuse_block=True, fuse_ends=True, num_layers=1)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    x = _mk(rng, 2, cfg.in_channels, *cfg.spatial)
    y = fno_mod.apply_fno(params, cfg, x, path="pallas")
    y_xla = fno_mod.apply_fno(
        params, dataclasses.replace(cfg, fuse_ends=False), x, path="xla")
    _allclose_rel(y, y_xla, 2e-4)
    fn = lambda xx: fno_mod.apply_fno(params, cfg, xx, path="pallas")
    assert count_pallas_calls(fn, x) == 1


def test_fused_ends_bf16_matches_staged_pallas():
    """bf16 policy under fuse_ends: parity against the bf16 staged-ends
    pallas path (the apples-to-apples reference — both quantize the same
    boundary activations; the f32 oracle differs by inherent bf16
    rounding, covered at f32 above)."""
    from repro.configs.fno import with_precision
    from repro.core import fno as fno_mod

    cfg0 = with_precision(get_config("fno2d", reduced=True), "bf16")
    cfg0 = dataclasses.replace(cfg0, fuse_block=True)
    cfg = dataclasses.replace(cfg0, fuse_ends=True)
    params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(6)
    x = _mk(rng, 2, cfg.in_channels, *cfg.spatial)
    y = fno_mod.apply_fno(params, cfg, x, path="pallas")
    y_ref = fno_mod.apply_fno(params, cfg0, x, path="pallas")
    assert y.dtype == jnp.bfloat16
    _allclose_rel(y.astype(jnp.float32), y_ref.astype(jnp.float32), 2e-2)


def test_train_step_fuse_block_smoke():
    """Convergence smoke with fuse_block=True: the fused-block train step
    overfits one batch, and its first-step loss/grad-norm match the
    unfused pallas step (same math, one kernel per block)."""
    from repro.core import fno as fno_mod
    from repro.optim import AdamW
    from repro.optim.schedule import constant
    from repro.train.train_step import make_train_step

    rng = np.random.default_rng(0)
    cfg0 = get_config("fno2d", reduced=True)
    batch = {"x": _mk(rng, 2, cfg0.in_channels, *cfg0.spatial),
             "y": _mk(rng, 2, cfg0.out_channels, *cfg0.spatial)}
    metrics = {}
    for fuse in (False, True):
        cfg = dataclasses.replace(cfg0, fuse_block=fuse)
        params = fno_mod.init_fno(jax.random.PRNGKey(0), cfg)
        opt = AdamW(lr=constant(3e-3))
        step = jax.jit(make_train_step(cfg, opt, fno_path="pallas"))
        state = opt.init(params)
        hist = []
        for _ in range(5):
            params, state, m = step(params, state, batch)
            hist.append(float(m["loss"]))
        assert np.isfinite(hist).all()
        assert hist[-1] < hist[0], hist
        metrics[fuse] = (hist, float(m["grad_norm"]))
    np.testing.assert_allclose(metrics[True][0][0], metrics[False][0][0],
                               rtol=1e-4)


def test_dgelu_matches_jax_gelu_grad():
    """The in-kernel gelu' closed form equals jax.grad of the activation
    core/fno.py applies (tanh-approximate jax.nn.gelu)."""
    from repro.kernels.engine import _dgelu

    z = jnp.linspace(-6.0, 6.0, 301, dtype=jnp.float32)
    ref = jax.vmap(jax.grad(lambda v: jax.nn.gelu(v, approximate=True)))(z)
    np.testing.assert_allclose(np.asarray(_dgelu(z)), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_cdft_wrappers_take_operand_dtype():
    """Satellite: the complex-pair standalone DFT wrappers honor
    operand_dtype like the real-input ones (the policy's spectral dtype
    on the partial path's core stages)."""
    rng = np.random.default_rng(4)
    xr = _mk(rng, 4, 16)
    xi = _mk(rng, 4, 16)
    fr32, fi32 = ops.truncated_cdft(xr, xi, 5, path="pallas")
    fr16, fi16 = ops.truncated_cdft(xr, xi, 5, path="pallas",
                                    operand_dtype="bfloat16")
    # bf16 operands perturb the result but stay within bf16 tolerance
    assert float(jnp.abs(fr16 - fr32).max()) > 0.0
    _allclose_rel(fr16, fr32, 2e-2)
    _allclose_rel(fi16, fi32, 2e-2)
    br32, bi32 = ops.padded_icdft(fr32, fi32, 16, path="pallas")
    br16, bi16 = ops.padded_icdft(fr32, fi32, 16, path="pallas",
                                  operand_dtype="bfloat16")
    assert float(jnp.abs(br16 - br32).max()) > 0.0
    _allclose_rel(br16, br32, 2e-2)
    _allclose_rel(bi16, bi32, 2e-2)
