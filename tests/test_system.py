"""End-to-end system behaviour: train a reduced model until the loss
drops, serve it, and check the public API surface holds together."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ALL_IDS, get_config
from repro.core import fno as fno_mod
from repro.data import pde
from repro.models import transformer as tf
from repro.optim import AdamW
from repro.optim.schedule import constant
from repro.train import serve_step
from repro.train.train_step import make_train_step


def test_fno2d_end_to_end_darcy():
    """Lifting -> spectral blocks -> projection learns Darcy on synthetic
    data (few steps, reduced size)."""
    cfg = get_config("fno2d", reduced=True)
    key = jax.random.PRNGKey(0)
    params = fno_mod.init_fno(key, cfg)
    opt = AdamW(lr=constant(1e-2), weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt, fno_path="xla"))
    state = opt.init(params)
    losses = []
    for i in range(25):
        batch = pde.darcy_batch(0, i, 4, cfg.spatial[0], iters=120)
        params, state, m = step(params, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < 0.5 * losses[0], losses[::6]


def test_lm_generation_loop():
    cfg = get_config("qwen2-1.5b", reduced=True)
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg, jnp.float32)
    prompts = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    prefill = jax.jit(serve_step.make_prefill_step(cfg, max_len=20))
    decode = jax.jit(serve_step.make_decode_step(cfg))
    logits, cache = prefill(params, {"tokens": prompts})
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    toks = [tok]
    for _ in range(7):
        tok, lg, cache = decode(params, cache, tok)
        toks.append(tok)
    gen = jnp.stack(toks, 1)
    assert gen.shape == (2, 8)
    assert int(cache["len"]) == 19  # 12 prompt + 7 decoded inputs
    # greedy decode is deterministic
    logits2, cache2 = prefill(params, {"tokens": prompts})
    tok2 = jnp.argmax(logits2, -1).astype(jnp.int32)
    np.testing.assert_array_equal(np.asarray(toks[0]), np.asarray(tok2))


def test_all_configs_resolve():
    for arch in ALL_IDS:
        cfg = get_config(arch)
        red = get_config(arch, reduced=True)
        assert red.param_count() < cfg.param_count()
