"""Data substrate: PDE solvers, determinism, pipeline behavior."""
import time

import jax.numpy as jnp
import numpy as np

from repro.data import pde, tokens
from repro.data.pipeline import PrefetchPipeline


def test_burgers_determinism_and_physics():
    b1 = pde.burgers_batch(0, 3, 4, 64)
    b2 = pde.burgers_batch(0, 3, 4, 64)
    np.testing.assert_array_equal(np.asarray(b1["x"]), np.asarray(b2["x"]))
    np.testing.assert_array_equal(np.asarray(b1["y"]), np.asarray(b2["y"]))
    # viscosity dissipates energy
    e0 = float(jnp.sum(b1["x"] ** 2))
    eT = float(jnp.sum(b1["y"] ** 2))
    assert eT < e0
    assert bool(jnp.isfinite(b1["y"]).all())


def test_darcy_residual_small():
    batch = pde.darcy_batch(0, 0, 2, 32, iters=300)
    a = np.asarray(batch["x"][:, 0]) * 10.0
    u = np.asarray(batch["y"][:, 0])
    # recompute residual of the discrete operator
    u_j = jnp.asarray(u)
    f = jnp.ones_like(u_j)
    scale = float(jnp.std(pde.darcy_solve(jnp.asarray(a), f, iters=300)))
    r = pde._darcy_apply(jnp.asarray(a), u_j * scale, 1.0 / (32 + 1)) - f
    rel = float(jnp.linalg.norm(r) / jnp.linalg.norm(f))
    assert rel < 0.05, rel


def test_diffusion3d_determinism_and_spectrum():
    b1 = pde.diffusion3d_batch(0, 2, 2, 16)
    b2 = pde.diffusion3d_batch(0, 2, 2, 16)
    np.testing.assert_array_equal(np.asarray(b1["x"]), np.asarray(b2["x"]))
    np.testing.assert_array_equal(np.asarray(b1["y"]), np.asarray(b2["y"]))
    assert b1["x"].shape == (2, 1, 16, 16, 16)
    assert bool(jnp.isfinite(b1["y"]).all())
    # diffusion damps high frequencies: the high-|k| energy fraction of
    # u(T) must be below that of u0. Mask on |k| magnitude (fftfreq), not
    # array corners — the full-FFT axes carry mirrored low-|k| energy at
    # the top indices.
    def hi_frac(u):
        a = np.asarray(u[:, 0])
        n = a.shape[-1]
        e = np.abs(np.fft.rfftn(a, axes=(-3, -2, -1))) ** 2
        kf = np.fft.fftfreq(n, 1.0 / n)
        kr = np.fft.rfftfreq(n, 1.0 / n)
        k2 = (kf[:, None, None] ** 2 + kf[None, :, None] ** 2
              + kr[None, None, :] ** 2)
        hi = k2 > 4.0 ** 2
        return e[:, hi].sum() / e.sum()
    assert hi_frac(b1["y"]) < hi_frac(b1["x"])


def test_token_batches_sharded_and_deterministic():
    full = tokens.token_batch(7, 5, batch=8, seq_len=16, vocab=100)
    s0 = tokens.token_batch(7, 5, batch=8, seq_len=16, vocab=100,
                            shard=0, num_shards=4)
    assert s0["tokens"].shape == (2, 16)
    again = tokens.token_batch(7, 5, batch=8, seq_len=16, vocab=100,
                               shard=0, num_shards=4)
    np.testing.assert_array_equal(np.asarray(s0["tokens"]),
                                  np.asarray(again["tokens"]))
    assert full["labels"].shape == (8, 16)
    assert int(full["tokens"].max()) < 100


def test_prefetch_pipeline_and_straggler_skip():
    calls = []

    def batch_fn(i):
        calls.append(i)
        if i == 2:
            time.sleep(0.5)  # straggling producer
        return {"i": i}

    p = PrefetchPipeline(batch_fn, depth=1)
    idx0, b0 = p.get(timeout=2.0)
    assert b0["i"] == idx0 == 0
    idx1, _ = p.get(timeout=2.0)
    assert idx1 == 1
    # batch 2 is slow: with a tight timeout we record skips but still
    # eventually progress
    idx2, _ = p.get(timeout=0.05)
    assert idx2 == 2 and p.skipped >= 1
    p.stop()
