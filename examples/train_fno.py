"""End-to-end FNO training driver (deliverable b): data generation →
sharded train step → checkpointing → restart-safe loop.

Reduced demo (runs in ~a minute on this CPU container):

    PYTHONPATH=src python examples/train_fno.py --steps 60

Full-scale target (the ~100M-parameter configuration; run on a real
accelerator — one step is ~0.9 TFLOP at batch 8):

    PYTHONPATH=src python examples/train_fno.py --full --steps 300 \
        --batch 8 --lr 3e-4

Rank sweep: --arch fno1d / fno2d / fno3d trains the matching PDE task
(Burgers / Darcy / 3D diffusion-reaction) through the same rank-generic
fused engine.

Mixed precision: --dtype bf16 selects the bf16 PrecisionPolicy — bf16
compute/spectral operands through the fused kernels (halving the
memory-bound layer's HBM traffic) with f32 master params, accumulators,
and AdamW update. --dtype f32 (default) is the pure-f32 policy.
"""
import argparse
import tempfile

import jax

from repro.configs import get_config
from repro.configs.fno import with_fuse_block, with_precision
from repro.core import fno
from repro.data import pde
from repro.optim import AdamW
from repro.optim.schedule import cosine_warmup
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-2)
    ap.add_argument("--arch", default=None,
                    choices=["fno1d", "fno2d", "fno2d-large", "fno3d"],
                    help="architecture/rank; picks the matching PDE task "
                         "(Burgers 1D / Darcy 2D / diffusion-reaction 3D)")
    ap.add_argument("--full", action="store_true",
                    help="fno2d-large (~134M params, per-mode weights); "
                         "shorthand for --arch fno2d-large at full size")
    ap.add_argument("--path", default="xla", choices=["ref", "xla", "pallas"],
                    help="pallas = fused kernels fwd AND bwd (custom_vjp); "
                         "no staged-XLA fallback")
    ap.add_argument("--variant", default="full", choices=["full", "partial"],
                    help="2D/3D pallas fusion: full (beyond-paper) or "
                         "partial (paper-faithful)")
    ap.add_argument("--dtype", default="f32", choices=["f32", "bf16"],
                    help="precision policy: bf16 = bf16 compute/spectral "
                         "operands with f32 master params + accumulators "
                         "(mixed precision); f32 = pure f32")
    ap.add_argument("--fuse-block", action="store_true",
                    help="pallas path: fuse each whole FNO block "
                         "(spectral + 1x1 bypass + bias + GELU) into ONE "
                         "pallas_call per layer, fwd and bwd")
    args = ap.parse_args()

    if args.full and args.arch not in (None, "fno2d-large"):
        ap.error("--full selects fno2d-large; it conflicts with "
                 f"--arch {args.arch}")
    if args.fuse_block and args.path != "pallas":
        ap.error("--fuse-block requires --path pallas (the staged paths "
                 "stay the parity oracle)")
    arch = args.arch or ("fno2d-large" if args.full else "fno2d")
    cfg = with_precision(get_config(arch, reduced=not args.full), args.dtype)
    if args.fuse_block:
        cfg = with_fuse_block(cfg)
    key = jax.random.PRNGKey(0)
    params = fno.init_fno(key, cfg)
    n = cfg.spatial[0]
    print(f"{cfg.name}: {cfg.param_count()/1e6:.1f}M params, "
          f"grid {cfg.spatial}, modes {cfg.modes}, "
          f"weights={cfg.weight_mode}, path={args.path}, "
          f"variant={args.variant}, dtype={args.dtype} "
          f"(compute={cfg.precision.compute_dtype}, "
          f"params={cfg.precision.param_dtype}), "
          f"fuse_block={cfg.fuse_block}")

    opt = AdamW(lr=cosine_warmup(args.lr, args.steps // 10 + 1, args.steps),
                weight_decay=0.0)
    step = jax.jit(make_train_step(cfg, opt, fno_path=args.path,
                                   fno_variant=args.variant))
    if cfg.ndim == 1:
        batch_fn = lambda i: pde.burgers_batch(0, i, args.batch, n)
    elif cfg.ndim == 2:
        batch_fn = lambda i: pde.darcy_batch(0, i, args.batch, n,
                                             iters=150 if args.full else 100)
    else:
        batch_fn = lambda i: pde.diffusion3d_batch(0, i, args.batch, n)

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tcfg = TrainerConfig(total_steps=args.steps, ckpt_every=50,
                             ckpt_dir=ckpt_dir, log_every=10)
        trainer = Trainer(tcfg, step, batch_fn, params, opt.init(params))
        out = trainer.run()
    for m in out["metrics"]:
        print(f"  step {m['step']:4d}  loss {m['loss']:.4f}  "
              f"gnorm {m['grad_norm']:.2f}  {m['dt']*1e3:.0f} ms")
    print(f"finished {out['final_step']} steps; "
          f"stragglers flagged: {out['stragglers']}")


if __name__ == "__main__":
    main()
