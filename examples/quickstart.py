"""Quickstart: the TurboFNO fused spectral layer in 30 lines.

    PYTHONPATH=src python examples/quickstart.py

Builds a small FNO-2D, runs the same input through the three execution
paths (staged jnp.fft reference, XLA truncated-DFT formulation, fused
Pallas kernel) and shows they agree — in f32 and under the bf16
PrecisionPolicy (bf16 kernel I/O, f32 accumulators); then takes a few
training steps on synthetic Darcy-flow data. For mixed-precision
training pass ``--dtype bf16`` to examples/train_fno.py.
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.configs.fno import with_precision
from repro.core import fno
from repro.data import pde
from repro.optim import AdamW
from repro.optim.schedule import constant
from repro.train.train_step import make_train_step

cfg = get_config("fno2d", reduced=True)
key = jax.random.PRNGKey(0)
params = fno.init_fno(key, cfg)
x = jax.random.normal(key, (2, cfg.in_channels, *cfg.spatial))

print(f"FNO-2D: {cfg.num_layers} layers, hidden={cfg.hidden}, "
      f"spatial={cfg.spatial}, modes={cfg.modes} "
      f"({cfg.param_count()/1e3:.0f}k params)")

outs = {p: fno.apply_fno(params, cfg, x, path=p)
        for p in ("ref", "xla", "pallas")}
for name, y in outs.items():
    err = float(jnp.abs(y - outs["ref"]).max())
    print(f"  path={name:7s} out={y.shape}  max|Δ vs ref|={err:.2e}")

y16 = fno.apply_fno(params, with_precision(cfg, "bf16"), x, path="pallas")
err = float(jnp.abs(y16.astype(jnp.float32) - outs["ref"]).max())
print(f"  path=pallas (bf16 policy) out dtype={y16.dtype}  "
      f"max|Δ vs f32 ref|={err:.2e}")

opt = AdamW(lr=constant(1e-2), weight_decay=0.0)
step = jax.jit(make_train_step(cfg, opt, fno_path="xla"))
state = opt.init(params)
print("training on synthetic Darcy flow:")
for i in range(10):
    batch = pde.darcy_batch(0, i, 4, cfg.spatial[0], iters=100)
    params, state, m = step(params, state, batch)
    if i % 3 == 0:
        print(f"  step {i:2d}  rel-L2 loss {float(m['loss']):.4f}")
print("done — see examples/train_fno.py for the full driver.")
