"""Batched LM serving: prefill + autoregressive decode with per-segment
KV caches (ring buffers on sliding-window layers).

    PYTHONPATH=src python examples/serve_lm.py --arch mixtral-8x7b
    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m

mixtral demonstrates ring-buffer SWA caches; mamba2 demonstrates O(1)
recurrent-state decode (no KV cache at all).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as tf
from repro.train import serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x7b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    assert cfg.is_decoder
    key = jax.random.PRNGKey(0)
    params = tf.init_lm(key, cfg, jnp.float32)
    max_len = args.prompt_len + args.new_tokens

    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    prefill = jax.jit(serve_step.make_prefill_step(cfg, max_len=max_len))
    decode = jax.jit(serve_step.make_decode_step(cfg, sample=True,
                                                 temperature=0.8))

    logits, cache = prefill(params, {"tokens": prompts})
    for i, seg in enumerate(cache["segments"]):
        kinds = {k: tuple(v.shape) for k, v in seg.items()}
        print(f"  cache segment {i}: {kinds}")

    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for step_i in range(args.new_tokens - 1):
        key, sk = jax.random.split(key)
        tok, _, cache = decode(params, cache, tok, sk)
        out.append(tok)
    jax.block_until_ready(tok)
    dt = (time.time() - t0) / max(args.new_tokens - 1, 1)
    gen = jnp.stack(out, 1)
    print(f"{args.arch}: batch={args.batch}, {dt*1e3:.1f} ms/token (CPU)")
    for b in range(min(2, args.batch)):
        print(f"  sampled[{b}]: {gen[b].tolist()}")


if __name__ == "__main__":
    main()
