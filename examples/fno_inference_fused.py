"""Serve an FNO with the fused TurboFNO kernel and compare the three
execution paths on identical inputs — parity + per-path wall time + the
derived HBM-traffic model that explains the TPU speedup.

    PYTHONPATH=src python examples/fno_inference_fused.py
"""
import os
import sys
import time

import jax
import jax.numpy as jnp

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
from benchmarks.pipelines import traffic_bytes  # noqa: E402
from repro.configs import get_config
from repro.core import fno

cfg = get_config("fno2d", reduced=True)
key = jax.random.PRNGKey(1)
params = fno.init_fno(key, cfg)
x = jax.random.normal(key, (4, cfg.in_channels, *cfg.spatial))

apply = {p: jax.jit(lambda pr, xx, p=p: fno.apply_fno(pr, cfg, xx, path=p))
         for p in ("ref", "xla", "pallas")}

ref = None
for name, fn in apply.items():
    y = jax.block_until_ready(fn(params, x))
    t0 = time.time()
    for _ in range(5):
        y = jax.block_until_ready(fn(params, x))
    dt = (time.time() - t0) / 5
    if ref is None:
        ref = y
    err = float(jnp.abs(y - ref).max())
    note = "(interpret mode on CPU — Pallas timing is not meaningful here)" \
        if name == "pallas" else ""
    print(f"path={name:7s}  {dt*1e3:8.1f} ms/call  max|Δ|={err:.2e} {note}")

h = cfg.hidden
n = cfg.spatial[0]
k = cfg.modes[0]
base = traffic_bytes(4, h, h, n, k, "baseline")
fused = traffic_bytes(4, h, h, n, k, "fused_full")
print(f"\nderived HBM traffic per layer (TPU model): staged {base/2**20:.1f}"
      f" MiB vs fused {fused/2**20:.1f} MiB — {base/fused:.1f}x reduction;"
      f"\nthe layer is memory-bound on v5e, so this ratio bounds the fused"
      f" kernel's speedup (EXPERIMENTS.md §Paper-claims).")
